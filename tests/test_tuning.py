"""Shape-bucketed autotuner: TuningDB persistence, sweep driver, selection
precedence (tuned → EMA → cost model → static), variant feasibility guards,
and the end-to-end config-application contract (DESIGN.md §9)."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CostModelScheduler, KernelRecord, KernelRegistry,
                        RuntimeAgent, TuneEntry, TuningDB, abstract_signature,
                        autotune, config_feasible, default_manifest,
                        shape_bucket, tuning_key)
from repro.core.tuning import dtype_tag


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _spy_record(seen, alias="SPY", platform="pallas", space=None, **kw):
    """A record whose fn appends every received kwargs dict to ``seen``."""
    def fn(a, **kwargs):
        seen.append(dict(kwargs))
        return a + 1.0

    if space is None:
        def space(a, **kwargs):
            return [dict(bm=64), dict(bm=128)]
    return KernelRecord(alias=alias, fn=fn, platform=platform,
                        tuning_space=space, **kw)


def _seed(db, record, args, config, seconds=1e-6, default_seconds=1e-3):
    sig = abstract_signature(args)
    key = tuning_key(record.platform, record.alias, shape_bucket(sig),
                     dtype_tag(sig))
    db.put(key, TuneEntry(config=config, seconds=seconds,
                          default_seconds=default_seconds, source="seed"))
    return key


# ---------------------------------------------------------------------------
# keys + buckets
# ---------------------------------------------------------------------------
def test_shape_bucket_and_dtype_tag():
    sig = abstract_signature((jnp.zeros((300, 5), jnp.float32),
                              jnp.zeros((128,), jnp.bfloat16), 7))
    assert shape_bucket(sig) == "512x8,128,-"
    assert dtype_tag(sig) == "float32+bfloat16+int"
    assert tuning_key("pallas", "MMM", "512x8", "float32") == \
        "pallas|MMM|512x8|float32"


# ---------------------------------------------------------------------------
# TuningDB persistence
# ---------------------------------------------------------------------------
def test_tuningdb_roundtrip(tmp_path):
    path = tmp_path / "tuning.json"
    db = TuningDB(path)
    ent = TuneEntry(config={"bm": 512}, seconds=2e-4, default_seconds=4e-4)
    db.put("pallas|MMM|512x512,512x512|float32", ent)
    assert db.save() == path
    warm = TuningDB(path)
    got = warm.get("pallas|MMM|512x512,512x512|float32")
    assert got is not None and got.config == {"bm": 512}
    assert got.seconds == pytest.approx(2e-4)
    assert got.frozen and got.speedup == pytest.approx(2.0)


def test_tuningdb_merge_on_save(tmp_path):
    """Two writers share one file: a plain overwrite must not clobber the
    other's winners, and conflicts resolve to the faster entry."""
    path = tmp_path / "tuning.json"
    a, b = TuningDB(path), TuningDB(path)
    a.put("k1", TuneEntry(config={"bm": 64}, seconds=5e-4,
                          default_seconds=6e-4))
    a.save()
    b.put("k2", TuneEntry(config={"bn": 128}, seconds=1e-4,
                          default_seconds=2e-4))
    b.put("k1", TuneEntry(config={"bm": 256}, seconds=1e-4,   # faster
                          default_seconds=6e-4))
    b.save()
    merged = TuningDB(path)
    assert set(merged.entries()) == {"k1", "k2"}
    assert merged.get("k1").config == {"bm": 256}
    # the slower conflicting entry never wins, regardless of save order
    a.save()
    assert TuningDB(path).get("k1").config == {"bm": 256}


def test_tuningdb_corrupt_file_recovery(tmp_path):
    path = tmp_path / "tuning.json"
    path.write_text("{not json at all")
    db = TuningDB(path)                        # must not raise
    assert len(db) == 0
    db.put("k", TuneEntry(config={}, seconds=1e-4, default_seconds=1e-4))
    assert db.save() == path                   # and can still persist
    assert TuningDB(path).get("k") is not None
    # valid JSON, wrong shape → cold; malformed row → skipped
    path.write_text(json.dumps({"entries": {
        "good": {"config": {}, "seconds": 1e-4, "default_seconds": 1e-4},
        "bad": {"seconds": "nope"}}}))
    db2 = TuningDB(path)
    assert set(db2.entries()) == {"good"}
    path.write_text(json.dumps([1, 2, 3]))
    assert len(TuningDB(path)) == 0


# ---------------------------------------------------------------------------
# feasibility guards
# ---------------------------------------------------------------------------
def test_config_feasible_against_variants():
    rec = _spy_record([])
    args = (jnp.zeros((8, 8)),)
    assert config_feasible(rec, {"bm": 64}, args)
    assert config_feasible(rec, {}, args)              # default: always ok
    assert not config_feasible(rec, {"bm": 4096}, args)
    assert not config_feasible(rec, {"bogus": 1}, args)


def test_variants_guard_small_and_odd_shapes():
    """Real kernel spaces collapse for tiny/odd shapes instead of emitting
    infeasible configs, and every emitted config runs correctly."""
    from repro import kernels
    kernels.register_all()
    from repro.core.registry import GLOBAL_REGISTRY
    from repro.kernels.matmul import mmm_ref

    rec = next(r for r in GLOBAL_REGISTRY.records("MMM")
               if r.platform == "pallas")
    a = jnp.asarray(np.random.RandomState(0).randn(5, 7), jnp.float32)
    b = jnp.asarray(np.random.RandomState(1).randn(7, 3), jnp.float32)
    variants = rec.variants(a, b)
    assert 1 <= len(variants) <= 12
    ref = np.asarray(mmm_ref(a, b))
    for cfg in variants:
        assert set(cfg) == {"bm", "bn", "bk"}
        assert all(isinstance(v, int) and v >= 1 for v in cfg.values())
        np.testing.assert_allclose(np.asarray(rec.fn(a, b, **cfg)), ref,
                                   rtol=2e-4, atol=2e-4)
    # a raising space is treated as empty, never fatal
    def bad_space(*args, **kw):
        raise ValueError("boom")
    broken = KernelRecord(alias="X", fn=lambda a: a, platform="jnp",
                          tuning_space=bad_space)
    assert broken.variants(a) == []


def test_variants_stable_across_shape_bucket():
    """The bucket invariant: every member of a shape bucket gets the same
    variant list, so a winner swept at one member is a feasible (applied)
    config for all of them — including non-power-of-two shapes."""
    from repro import kernels
    kernels.register_all()
    from repro.core.registry import GLOBAL_REGISTRY

    rec = next(r for r in GLOBAL_REGISTRY.records("MMM")
               if r.platform == "pallas")
    swept = (jnp.zeros((512, 512)), jnp.zeros((512, 512)))
    member = (jnp.zeros((300, 400)), jnp.zeros((400, 290)))
    sig_a, sig_b = abstract_signature(swept), abstract_signature(member)
    assert shape_bucket(sig_a) == shape_bucket(sig_b)    # same DB key …
    assert rec.variants(*swept) == rec.variants(*member)  # … same variants
    for cfg in rec.variants(*swept):
        assert config_feasible(rec, cfg, member)
    # the largest (bucket-extent) candidate is always offered, even on
    # limit=2 axes — it is the cross-bucket anchor
    from repro.kernels.common import block_choices
    assert block_choices(512, 128, limit=2) == (128, 512)
    assert block_choices(300, 128, limit=2) == (128, 512)
    assert block_choices(4992, 128, limit=4)[-1] == 8192


# ---------------------------------------------------------------------------
# selection precedence (DESIGN.md §9 ladder)
# ---------------------------------------------------------------------------
def test_tuned_entry_beats_ema_and_cost_model():
    seen = []
    rec = _spy_record(seen)
    sched = CostModelScheduler()
    args = (jnp.zeros((64, 64)),)
    sig = abstract_signature(args)
    # EMA says 5ms; cost model absent
    for _ in range(3):
        sched.observe(rec, sig, 5e-3)
    assert sched.estimate(rec, sig, args) == pytest.approx(5e-3)
    # a tuned entry overrides the EMA for the same record
    _seed(sched.tuning, rec, args, {"bm": 64}, seconds=1e-6)
    assert sched.estimate(rec, sig, args) == pytest.approx(1e-6)
    assert sched.tuned_config(rec, args) == {"bm": 64}


def test_tuned_entry_flips_record_choice():
    """A tuned entry on the statically-dispreferred record outranks the
    preferred record's EMA — rung 1 beats rung 2 across records too."""
    reg = KernelRegistry()
    seen = []
    slow = KernelRecord(alias="K", fn=lambda a: a + 5.0, platform="xla",
                        priority=10)
    fast = _spy_record(seen, alias="K", platform="jnp", priority=0,
                       is_failsafe=True)
    reg.register(slow)
    reg.register(fast)
    sched = CostModelScheduler()
    args = (jnp.zeros(4),)
    sig = abstract_signature(args)
    for _ in range(3):
        sched.observe(slow, sig, 1e-4)     # xla measured fast-ish
    _seed(sched.tuning, fast, args, {"bm": 64}, seconds=1e-6)
    agent = RuntimeAgent(registry=reg, manifest=default_manifest(),
                         scheduler=sched)
    cr = agent.claim("K")
    agent.send(args, cr)
    np.testing.assert_allclose(np.asarray(agent.recv(cr)), 1.0)  # jnp won
    assert seen and seen[-1] == {"bm": 64}  # and ran at the tuned config


def test_stale_infeasible_entry_falls_through():
    """A tuned config the space no longer offers is ignored: the estimate
    falls back to the EMA and no config kwargs are applied."""
    seen = []
    rec = _spy_record(seen)
    sched = CostModelScheduler()
    args = (jnp.zeros((64, 64)),)
    sig = abstract_signature(args)
    for _ in range(3):
        sched.observe(rec, sig, 7e-3)
    _seed(sched.tuning, rec, args, {"bm": 9999}, seconds=1e-6)  # infeasible
    assert sched.estimate(rec, sig, args) == pytest.approx(7e-3)  # EMA rung
    assert sched.tuned_config(rec, args) is None
    agent = RuntimeAgent(registry=None, manifest=default_manifest(),
                         scheduler=sched)
    agent.registry = KernelRegistry()
    agent.registry.register(rec)
    agent.dispatch("SPY", *args)
    assert seen[-1] == {}                      # no stale kwargs injected


def test_dispatch_applies_tuned_config_via_spy():
    """Acceptance: a seeded TuningDB entry changes the config halo_dispatch
    uses — asserted via spy — with zero host-program changes."""

    seen = []
    reg = KernelRegistry()
    rec = _spy_record(seen, is_failsafe=True)
    reg.register(rec)
    args = (jnp.zeros((32, 32)),)
    db = TuningDB()
    _seed(db, rec, args, {"bm": 128})
    session = RuntimeAgent(registry=reg, manifest=default_manifest(),
                           scheduler=CostModelScheduler(tuning_db=db))
    out = session.dispatch("SPY", *args)       # the host line never changes
    np.testing.assert_allclose(np.asarray(out), 1.0)
    assert seen[-1] == {"bm": 128}
    # DRPC path applies the same config
    cr = session.claim("SPY")
    session.send(args, cr)
    session.recv(cr)
    assert seen[-1] == {"bm": 128}
    # explicit caller kwargs beat the tuned config
    session.dispatch("SPY", *args, bm=8)
    assert seen[-1] == {"bm": 8}
    session.finalize()


def test_halo_dispatch_env_seeded_db(tmp_path, monkeypatch):
    """Whole-machinery variant: the DB arrives via HALO_TUNING_DB, flows
    through CostModelScheduler.default() into the process session, and
    reshapes halo_dispatch — no host-program change anywhere."""
    from repro.core import MPIX_Finalize, MPIX_Initialize, halo_dispatch

    seen = []
    reg = KernelRegistry()
    rec = _spy_record(seen, is_failsafe=True)
    reg.register(rec)
    args = (jnp.zeros((32, 32)),)
    path = tmp_path / "db.json"
    db = TuningDB(path)
    _seed(db, rec, args, {"bm": 64})
    db.save()
    monkeypatch.setenv("HALO_TUNING_DB", str(path))
    try:
        MPIX_Initialize(registry=reg)
        halo_dispatch("SPY", *args)
        assert seen[-1] == {"bm": 64}
    finally:
        MPIX_Finalize()


def test_scheduler_without_tuning_db():
    """tuning_db=False disables rung 1 entirely (and nothing crashes)."""
    seen = []
    rec = _spy_record(seen)
    sched = CostModelScheduler(tuning_db=False)
    assert sched.tuning is None
    args = (jnp.zeros((16, 16)),)
    assert sched.tuned_config(rec, args) is None
    assert sched.estimate(rec, abstract_signature(args), args) is None


# ---------------------------------------------------------------------------
# sweep driver
# ---------------------------------------------------------------------------
def test_autotune_sweep_commits_and_freezes():
    calls = []

    def fn(a, bm=None):
        calls.append(bm)
        return a

    ticks = iter(range(1000))

    def timer():
        return next(ticks) * 1e-3

    rec = KernelRecord(alias="K", fn=fn, platform="jnp",
                       tuning_space=lambda a, **kw: [dict(bm=64)])
    db = TuningDB()
    res = autotune(rec, (jnp.zeros((8, 8)),), db=db, repeats=2, warmup=1,
                   timer=timer)
    assert res.swept and res.entry.frozen
    assert [cfg for cfg, _ in res.timings] == [{}, {"bm": 64}]
    assert db.get(res.key) is res.entry
    # frozen: the second call does not re-sweep …
    n = len(calls)
    res2 = autotune(rec, (jnp.zeros((8, 8)),), db=db, repeats=2, timer=timer)
    assert not res2.swept and len(calls) == n
    # … unless forced
    res3 = autotune(rec, (jnp.zeros((8, 8)),), db=db, repeats=2, force=True,
                    timer=timer)
    assert res3.swept and len(calls) > n


def test_autotune_noise_keeps_default():
    """A variant inside the min_gain noise band must not displace the
    default config."""
    times = {None: 1.000, 64: 0.995}           # 0.5% "win": pure noise
    clock = [0.0]

    def fn(a, bm=None):
        clock[0] += times[bm]
        return a

    rec = KernelRecord(alias="K", fn=fn, platform="jnp",
                       tuning_space=lambda a, **kw: [dict(bm=64)])
    res = autotune(rec, (jnp.zeros(4),), repeats=2, warmup=1,
                   timer=lambda: clock[0])
    assert res.entry.config == {}              # default retained
    # a real win (beyond min_gain) is committed
    times[64] = 0.5
    res2 = autotune(rec, (jnp.zeros(4),), repeats=2, warmup=1,
                    timer=lambda: clock[0])
    assert res2.entry.config == {"bm": 64}
    assert res2.entry.speedup == pytest.approx(2.0)


def test_autotune_skips_raising_variant():
    def fn(a, bm=None):
        if bm == 64:
            raise RuntimeError("infeasible after all")
        return a

    rec = KernelRecord(alias="K", fn=fn, platform="jnp",
                       tuning_space=lambda a, **kw: [dict(bm=64),
                                                     dict(bm=128)])
    res = autotune(rec, (jnp.zeros(4),), repeats=1)
    assert {"bm": 64} not in [cfg for cfg, _ in res.timings]
    assert {"bm": 128} in [cfg for cfg, _ in res.timings]


def test_cpu_sweep_smoke_cli(tmp_path, capsys):
    """End-to-end CLI smoke: tiny sweep, DB written, report prints."""
    from repro.launch import tune

    path = tmp_path / "db.json"
    assert tune.main(["--smoke", "--db", str(path),
                      "--aliases", "MMM,EWMM", "--report"]) == 0
    assert path.exists()
    db = TuningDB(path)
    assert len(db) >= 2                        # one bucket per alias
    assert all(e.frozen for e in db.entries().values())
    out = capsys.readouterr().out
    assert "pallas|MMM|" in out and "gain_x" in out
    # re-run: everything frozen, nothing re-swept
    assert tune.main(["--smoke", "--db", str(path),
                      "--aliases", "MMM,EWMM"]) == 0
    assert "frozen (skipped)" in capsys.readouterr().out
