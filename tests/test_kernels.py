"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv1d import conv1d, conv1d_ref
from repro.kernels.ewise import ewmd, ewmd_ref, ewmm, ewmm_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.flash_attention.xla import mea_attention
from repro.kernels.jacobi import jacobi_solve, jacobi_step, jacobi_step_ref
from repro.kernels.matmul import mmm, mmm_ref
from repro.kernels.matmul.ref import mmm_xla
from repro.kernels.moe_ffn import grouped_ffn, grouped_ffn_ref
from repro.kernels.mvm import mvm, mvm_ref
from repro.kernels.rmsnorm import rmsnorm, rmsnorm_ref
from repro.kernels.rmsnorm.ref import rmsnorm_xla
from repro.kernels.spmm import (bell_to_dense, dense_to_bell,
                                random_block_sparse, smmm, smmm_ref)
from repro.kernels.ssd import ssd_chunked, ssd_decode_step, ssd_ref
from repro.kernels.vdp import vdp, vdp_ref

F32, BF16 = jnp.float32, jnp.bfloat16


def tol(dt):
    return dict(rtol=3e-2, atol=3e-2) if dt == BF16 else dict(rtol=2e-4,
                                                              atol=2e-4)


@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (128, 256, 128), (100, 300, 50),
                                   (7, 13, 9), (512, 129, 257)])
@pytest.mark.parametrize("dt", [F32, BF16])
def test_mmm_sweep(rng, m, k, n, dt):
    a = jax.random.normal(rng, (m, k), dt)
    b = jax.random.normal(rng, (k, n), dt)
    np.testing.assert_allclose(np.asarray(mmm(a, b), np.float32),
                               np.asarray(mmm_ref(a, b), np.float32),
                               **tol(dt))


def test_mmm_xla_matches_ref(rng):
    a = jax.random.normal(rng, (64, 96), F32)
    b = jax.random.normal(rng, (96, 32), F32)
    np.testing.assert_allclose(mmm_xla(a, b), mmm_ref(a, b), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("shape", [(16, 16), (100, 257), (8, 1024)])
def test_ewise_sweep(rng, shape):
    a = jax.random.normal(rng, shape)
    b = jax.random.normal(rng, shape) + 3.0
    np.testing.assert_allclose(ewmm(a, b), ewmm_ref(a, b), rtol=1e-6)
    np.testing.assert_allclose(ewmd(a, b), ewmd_ref(a, b), rtol=1e-5)


@pytest.mark.parametrize("m,k", [(64, 64), (200, 333), (1000, 100)])
def test_mvm_sweep(rng, m, k):
    a = jax.random.normal(rng, (m, k))
    x = jax.random.normal(rng, (k,))
    np.testing.assert_allclose(mvm(a, x), mvm_ref(a, x), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [64, 1000, 100_000])
def test_vdp_sweep(rng, n):
    x = jax.random.normal(rng, (n,))
    y = jax.random.normal(rng, (n,))
    np.testing.assert_allclose(vdp(x, y), vdp_ref(x, y), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("n", [50, 150, 384])
def test_jacobi_step_and_solve(rng, n):
    a = jax.random.normal(rng, (n, n)) + n * jnp.eye(n)
    b = jax.random.normal(rng, (n,))
    x0 = jnp.zeros(n)
    np.testing.assert_allclose(jacobi_step(a, x0, b),
                               jacobi_step_ref(a, x0, b), rtol=1e-4, atol=1e-5)
    xs = jacobi_solve(a, b, iters=30)
    np.testing.assert_allclose(a @ xs, b, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,k", [(256, 5), (5000, 17), (1024, 64)])
def test_conv1d_sweep(rng, n, k):
    x = jax.random.normal(rng, (n,))
    w = jax.random.normal(rng, (k,))
    np.testing.assert_allclose(conv1d(x, w), conv1d_ref(x, w), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("m,k,bm,bk,density", [
    (256, 384, 64, 128, 0.3), (128, 128, 32, 128, 0.5), (512, 256, 128, 128, 0.1)])
def test_smmm_sweep(rng, m, k, bm, bk, density):
    a = random_block_sparse(rng, m, k, bm, bk, density)
    vals, idx = dense_to_bell(a, bm, bk)
    np.testing.assert_allclose(bell_to_dense(vals, idx, k), a)
    b = jax.random.normal(rng, (k, 200))
    np.testing.assert_allclose(smmm(vals, idx, b), smmm_ref(a, b), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("shape,d", [((4, 64), 64), ((2, 3, 300), 300),
                                     ((1, 12288), 12288)])
def test_rmsnorm_sweep(rng, shape, d):
    x = jax.random.normal(rng, shape)
    g = jax.random.normal(rng, (d,))
    np.testing.assert_allclose(rmsnorm(x, g), rmsnorm_ref(x, g), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(rmsnorm_xla(x, g), rmsnorm_ref(x, g),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,h,hkv,sq,skv,d,causal,win,pfx", [
    (2, 4, 2, 128, 128, 64, True, None, 0),
    (1, 8, 1, 100, 100, 80, True, None, 0),
    (2, 4, 4, 64, 256, 32, True, None, 0),
    (1, 4, 2, 128, 128, 64, True, 48, 0),
    (1, 4, 2, 96, 96, 64, True, None, 32),
    (1, 2, 2, 128, 128, 128, False, None, 0),
    (1, 4, 1, 1, 512, 128, True, None, 0),
])
def test_flash_attention_sweep(rng, b, h, hkv, sq, skv, d, causal, win, pfx):
    q = jax.random.normal(rng, (b, h, sq, d))
    k = jax.random.normal(rng, (b, hkv, skv, d))
    v = jax.random.normal(rng, (b, hkv, skv, d))
    ref = attention_ref(q, k, v, causal=causal, window=win, prefix_len=pfx)
    out = flash_attention(q, k, v, causal=causal, window=win, prefix_len=pfx)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
    out2 = mea_attention(q, k, v, causal=causal, window=win, prefix_len=pfx,
                         bk=64)
    np.testing.assert_allclose(out2, ref, rtol=2e-4, atol=2e-4)


def test_ssd_chunked_vs_ref(rng):
    B, S, H, P, G, N = 2, 256, 4, 16, 2, 32
    ks = jax.random.split(rng, 6)
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    d = jax.random.normal(ks[5], (H,)) * 0.1
    for chunk in (32, 64, 256):
        out = ssd_chunked(x, dt, a, bm, cm, d, chunk=chunk)
        np.testing.assert_allclose(out, ssd_ref(x, dt, a, bm, cm, d),
                                   rtol=2e-4, atol=2e-4)
    # final state consistency: chunked == step-by-step
    y, hfin = ssd_chunked(x, dt, a, bm, cm, d, chunk=64, return_state=True)
    h = jnp.zeros((B, H, P, N), jnp.float32)
    for t in range(S):
        h, _ = ssd_decode_step(h, x[:, t], dt[:, t], a, bm[:, t], cm[:, t], d)
    np.testing.assert_allclose(hfin, h, rtol=1e-3, atol=1e-3)


def test_grouped_ffn(rng):
    ks = jax.random.split(rng, 4)
    xe = jax.random.normal(ks[0], (4, 8, 32))
    wg = jax.random.normal(ks[1], (4, 32, 64)) * 0.1
    wu = jax.random.normal(ks[2], (4, 32, 64)) * 0.1
    wd = jax.random.normal(ks[3], (4, 64, 32)) * 0.1
    np.testing.assert_allclose(grouped_ffn(xe, wg, wu, wd),
                               grouped_ffn_ref(xe, wg, wu, wd),
                               rtol=2e-4, atol=2e-5)


# ---- gradients through the pallas custom-vjp wrappers ------------------------
def test_mmm_grad(rng):
    a = jax.random.normal(rng, (64, 96))
    b = jax.random.normal(rng, (96, 32))
    g1 = jax.grad(lambda a, b: mmm(a, b).sum(), argnums=(0, 1))(a, b)
    g2 = jax.grad(lambda a, b: mmm_ref(a, b).sum(), argnums=(0, 1))(a, b)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, rtol=1e-4, atol=1e-4)


def test_rmsnorm_grad(rng):
    x = jax.random.normal(rng, (4, 64))
    g = jax.random.normal(rng, (64,))
    g1 = jax.grad(lambda x, g: rmsnorm(x, g).sum(), argnums=(0, 1))(x, g)
    g2 = jax.grad(lambda x, g: rmsnorm_ref(x, g).sum(), argnums=(0, 1))(x, g)
    for u, v in zip(g1, g2):
        np.testing.assert_allclose(u, v, rtol=1e-4, atol=1e-4)


def test_flash_attention_grad(rng):
    q = jax.random.normal(rng, (1, 4, 64, 32))
    k = jax.random.normal(rng, (1, 2, 64, 32))
    v = jax.random.normal(rng, (1, 2, 64, 32))
    g1 = jax.grad(lambda q, k, v: flash_attention(q, k, v).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: attention_ref(q, k, v).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for u, v_ in zip(g1, g2):
        np.testing.assert_allclose(u, v_, rtol=2e-4, atol=2e-4)


# -- data-reorganization + spectral class (paper Table II rows 9–11) ----------
from repro.kernels.fft import fft, fft_ref                      # noqa: E402
from repro.kernels.sorthist import hist, hist_ref, sort, sort_ref  # noqa: E402


@pytest.mark.parametrize("m,n", [(1, 64), (4, 128), (3, 500), (8, 1024)])
def test_fft_sweep(rng, m, n):
    x = jax.random.normal(rng, (m, n), F32)
    out = fft(x)
    ref = np.fft.fft(np.asarray(x), axis=-1)
    assert out.dtype == jnp.complex64
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-3,
                               atol=2e-3 * np.sqrt(n))
    np.testing.assert_allclose(np.asarray(fft_ref(x)), ref, rtol=1e-4,
                               atol=1e-3)


@pytest.mark.parametrize("shape", [(64,), (4, 100), (2, 3, 128), (5, 1000)])
@pytest.mark.parametrize("dt", [F32, BF16])
def test_sort_sweep(rng, shape, dt):
    x = jax.random.normal(rng, shape, dt)
    out = sort(x)
    assert out.shape == shape and out.dtype == dt
    np.testing.assert_array_equal(
        np.asarray(out, np.float32),
        np.sort(np.asarray(x, np.float32), axis=-1))
    np.testing.assert_array_equal(np.asarray(sort_ref(x), np.float32),
                                  np.sort(np.asarray(x, np.float32), -1))


@pytest.mark.parametrize("n,bins,lo,hi", [(256, 16, 0.0, 1.0),
                                          (1000, 64, -2.0, 2.0),
                                          (65536, 128, -1.0, 3.0),
                                          (100, 7, 0.0, 0.5)])
def test_hist_sweep(rng, n, bins, lo, hi):
    x = jax.random.normal(rng, (n,), F32)
    out = np.asarray(hist(x, bins=bins, lo=lo, hi=hi))
    assert out.shape == (bins,)
    # the kernel reproduces the family contract (hist_ref) bit-exactly
    np.testing.assert_array_equal(
        out, np.asarray(hist_ref(x, bins=bins, lo=lo, hi=hi)))
    # …and np.histogram up to f32-vs-f64 edge rounding: a value exactly on
    # a bin edge may land one bin over, so mass is conserved and any
    # per-bin delta is a neighbour swap
    ref, _ = np.histogram(np.asarray(x), bins=bins, range=(lo, hi))
    assert out.sum() == ref.sum()
    assert np.abs(out - ref).max() <= 2


def test_hist_total_mass_only_counts_in_range(rng):
    x = jnp.concatenate([jnp.linspace(0.0, 1.0, 101),
                         jnp.asarray([-0.5, 1.5, jnp.inf, -jnp.inf])])
    out = hist(x, bins=10, lo=0.0, hi=1.0)
    assert float(out.sum()) == 101.0      # edges included, outliers dropped
