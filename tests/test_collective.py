"""Collective verbs over device groups (DESIGN.md §10): verb semantics,
graph-captured vs eager parity, call-order hazard edges, member-failure
quarantine + re-placement mid-collective, and the group-aware scheduler
ranking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CostModelScheduler, GraphError, KernelRecord,
                        KernelRegistry, RuntimeAgent, default_manifest,
                        halo_graph)
from repro.distributed.sharding import partition_slices
from repro.kernels import register_all
from repro.testing.faults import faulty_record


@pytest.fixture()
def agent():
    registry = KernelRegistry()
    register_all(registry)
    a = RuntimeAgent(registry=registry, manifest=default_manifest())
    yield a
    a.finalize()


@pytest.fixture()
def comm(agent):
    return agent.comm_split(["xla", "jnp"])


def _x(shape=(4, 6), seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


# -- verb semantics -----------------------------------------------------------
def test_bcast_copies_to_every_member(comm):
    x = _x()
    copies = comm.bcast(x)
    assert len(copies) == comm.size
    for c in copies:
        np.testing.assert_array_equal(np.asarray(c), np.asarray(x))


def test_scatter_gather_roundtrip(comm):
    x = _x((8, 3))
    shards = comm.scatter(x)
    assert [s.shape for s in shards] == [(4, 3), (4, 3)]
    np.testing.assert_array_equal(np.asarray(shards[1]), np.asarray(x[4:]))
    np.testing.assert_array_equal(np.asarray(comm.gather(shards)),
                                  np.asarray(x))


def test_scatter_rejects_indivisible_axis(comm):
    with pytest.raises(ValueError, match="does not divide evenly"):
        comm.scatter(_x((5, 2)))


def test_partition_slices():
    assert partition_slices(8, 2) == ((0, 4), (4, 4))
    assert partition_slices(6, 3) == ((0, 2), (2, 2), (4, 2))
    with pytest.raises(ValueError):
        partition_slices(7, 2)
    with pytest.raises(ValueError):
        partition_slices(4, 0)


def test_reduce_sum_and_prod(comm):
    x = _x((4, 6))
    shards = comm.scatter(x)
    np.testing.assert_array_equal(
        np.asarray(comm.reduce(shards, op="sum")),
        np.asarray(shards[0] + shards[1]))
    np.testing.assert_allclose(
        np.asarray(comm.reduce(shards, op="prod")),
        np.asarray(shards[0] * shards[1]), rtol=1e-6)


def test_reduce_scalars_vdp_residual_pattern(comm):
    parts = [jnp.float32(1.25), jnp.float32(2.5)]
    assert float(comm.reduce(parts, op="sum")) == 3.75
    # gather of scalars stacks one element per rank
    np.testing.assert_array_equal(np.asarray(comm.gather(parts)),
                                  np.asarray([1.25, 2.5], np.float32))


def test_allreduce_every_member_gets_identical_value(comm):
    shards = comm.scatter(_x((6, 2)))
    outs = comm.allreduce(shards, op="sum")
    assert len(outs) == comm.size
    ref = np.asarray(shards[0] + shards[1])
    for o in outs:
        np.testing.assert_array_equal(np.asarray(o), ref)


def test_allgather(comm):
    x = _x((8,))
    shards = comm.scatter(x)
    for full in comm.allgather(shards):
        np.testing.assert_array_equal(np.asarray(full), np.asarray(x))


def test_reduce_unknown_op_raises(comm):
    with pytest.raises(ValueError, match="no registered combine kernel"):
        comm.reduce([_x(), _x()], op="median")


def test_custom_binary_alias_as_reduce_op(agent):
    agent.registry.register(KernelRecord(
        alias="EWMAX", fn=jnp.maximum, platform="jnp", is_failsafe=True))
    comm = agent.comm_split(["xla", "jnp"])
    a, b = _x(seed=1), _x(seed=2)
    np.testing.assert_array_equal(np.asarray(comm.reduce([a, b], op="max")),
                                  np.maximum(np.asarray(a), np.asarray(b)))


def test_per_rank_length_validation(comm):
    with pytest.raises(ValueError, match="one value per member rank"):
        comm.reduce([_x()], op="sum")
    with pytest.raises(ValueError, match="rank 3 out of range"):
        comm.bcast(_x(), root=3)


def test_comm_split_validation(agent):
    with pytest.raises(ValueError, match="no virtualization agent"):
        agent.comm_split(["gpu-of-theseus"])
    with pytest.raises(ValueError, match="at least one member"):
        agent.comm_split([])
    # default group spans available non-failsafe substrates
    comm = agent.comm_split()
    assert comm.size >= 2 and "jnp" not in comm.platforms


def test_freed_comm_and_finalize_invalidation(agent):
    comm = agent.comm_split(["xla"])
    comm.free()
    with pytest.raises(RuntimeError, match="was freed"):
        comm.bcast(_x())
    comm2 = agent.comm_split(["xla"])
    agent.finalize()
    assert comm2.freed


# -- member placement ---------------------------------------------------------
def test_member_stages_pin_to_member_agents(comm):
    """Each bcast COPY stage runs on its member's agent (fan-out on the
    member worker queues, not wherever preference points)."""
    submitted = []
    for platform, va in comm.session.agents.items():
        orig = va.submit

        def spy(fn, future=None, _p=platform, _o=orig, **kw):
            submitted.append(_p)
            return _o(fn, future=future, **kw)

        va.submit = spy
    nodes = comm.ibcast(_x())
    [n.result(timeout=30) for n in nodes]
    assert [n.platform for n in nodes] == ["xla", "jnp"]
    assert {"xla", "jnp"} <= set(submitted)


def test_map_member_compute(comm):
    a0, a1 = _x(seed=1), _x(seed=2)
    outs = comm.map("EWMM", [(a0, a0), (a1, a1)])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(a0 * a0),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(a1 * a1),
                               rtol=1e-6)


def test_eager_future_chaining_across_collectives(comm):
    """i-verb futures from one (already launched) collective feed the next
    collective's payloads: cross-graph dependencies gate via callbacks."""
    shards = comm.scatter(_x((6, 4)))
    doubled = comm.imap("EWADD", list(zip(shards, shards)))
    out = comm.reduce(doubled, op="sum")
    ref = 2 * (np.asarray(shards[0]) + np.asarray(shards[1]))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6)


# -- graph capture ------------------------------------------------------------
def test_captured_bcast_reduce_diamond_matches_eager(comm):
    """bcast → member compute → reduce as ONE captured graph: multi-parent
    reduce nodes, parity with the eager run, per-node placements set."""
    x = _x((4, 6))
    copies = comm.bcast(x)
    sq = comm.map("EWMM", [(c, c) for c in copies])
    ref = np.asarray(comm.reduce(sq, op="sum"))

    with halo_graph(session=comm.session) as g:
        ncopies = comm.ibcast(x)
        nsq = comm.imap("EWMM", [(c, c) for c in ncopies])
        nred = comm.ireduce(nsq, op="sum")
    # diamond shape: the reduce combine has one parent per member branch
    assert [p.alias for p in nred.parents] == ["EWMM", "EWMM"]
    assert len(g.nodes) == 5
    out = np.asarray(nred.result(timeout=60))
    np.testing.assert_array_equal(out, ref)
    assert all(p is not None for p in g.placements().values())


def test_capture_order_hazard_edges_between_collectives(comm):
    """Two collectives on one comm in one capture serialize in call order
    even with no data dependency (MPI call-order semantics)."""
    with halo_graph(session=comm.session, launch=False) as g:
        first = comm.ibcast(_x(seed=1))
        second = comm.ibcast(_x(seed=2))
    for node in second:
        assert any(p in first for p in node.parents)
    g.launch()
    g.wait(timeout=60)


def test_recycled_graph_id_does_not_wire_stale_hazard_edges(comm):
    """A fresh capture can reuse the ``id()`` of a dead graph whose tails
    entry survived the stale sweep; wiring those completed foreign nodes
    as hazard parents would hang the new graph's roots forever (they
    never decrement).  The seal must reject tails it does not own."""
    with halo_graph(session=comm.session) as g1:
        stale = comm.ibcast(_x(seed=1))
    jax.block_until_ready([n.result(timeout=60) for n in stale])
    with halo_graph(session=comm.session) as g2:
        # simulate id(g2) == id(g1): the dead graph's tails keyed as ours
        comm._tails = {id(g2): list(stale)}
        out = comm.ibcast(_x(seed=2))
    for node in out:
        assert all(g2.owns(p) for p in node.parents)
    np.testing.assert_array_equal(
        np.asarray(out[0].result(timeout=60)), np.asarray(_x(seed=2)))
    del g1


def test_blocking_collective_inside_capture_raises(comm):
    with halo_graph(session=comm.session, launch=False):
        with pytest.raises(GraphError, match="would deadlock"):
            comm.bcast(_x())


def test_scatter_of_completed_node_unwraps(comm):
    """A finished collective's node is a concrete value: scatter chained
    off it must unwrap, not demand a pre-capture payload."""
    x = _x((8,))
    copies = comm.ibcast(x)
    [c.result(timeout=30) for c in copies]
    shards = comm.scatter(copies[0])
    np.testing.assert_array_equal(np.asarray(shards[1]), np.asarray(x[4:]))


def test_scatter_of_live_node_inside_capture_raises(comm):
    with halo_graph(session=comm.session, launch=False):
        nodes = comm.ibcast(_x((4, 4)))
        with pytest.raises(GraphError, match="concrete payload"):
            comm.iscatter(nodes[0])


def test_captured_multi_iteration_allreduce_jacobi_parity(comm):
    """Two captured allgather→MVM→update→allreduce iterations (the
    collective_jacobi example structure) match the eager run bit-for-bit:
    orchestration must not change the numbers."""
    x = _x((8,))
    A = [_x((4, 8), seed=11), _x((4, 8), seed=12)]   # member row blocks

    shards0 = comm.scatter(x)

    def one_pass(gathered, mapped, reduced):
        cur, res = list(shards0), None
        for _ in range(2):
            full = gathered(cur)
            p = mapped("MVM", list(zip(A, full)))
            cur = mapped("EWADD", list(zip(p, cur)))
            s = mapped("VDP", list(zip(cur, cur)))
            res = reduced(s)
        return cur, res

    cur, res = one_pass(comm.allgather, comm.map,
                        lambda s: comm.allreduce(s, op="sum"))
    ref_x = np.asarray(comm.gather(cur))
    ref_res = float(res[0])

    with halo_graph(session=comm.session) as g:
        cur, res = one_pass(comm.iallgather, comm.imap,
                            lambda s: comm.iallreduce(s, op="sum"))
        out = comm.igather(cur)
    np.testing.assert_array_equal(np.asarray(out.result(timeout=60)), ref_x)
    assert float(res[0].result(timeout=60)) == ref_res
    assert all(p is not None for p in g.placements().values())


# -- failure paths ------------------------------------------------------------
def _faulty_registry():
    """EWADD with a faulty xla record and a correct jnp fail-safe, plus a
    per-member PART compute alias (faulty on xla too)."""
    reg = KernelRegistry()
    register_all(reg)
    reg.deregister("EWADD", "xla")
    reg.deregister("EWADD", "pallas")
    reg.register(faulty_record("EWADD", platform="xla",
                               message="xla combine died"))
    reg.register(faulty_record("PART", platform="xla",
                               message="xla member compute died"))
    reg.register(KernelRecord(alias="PART", fn=lambda a: a * 3.0,
                              platform="jnp", is_failsafe=True))
    return reg


def test_member_quarantine_mid_allreduce_bit_identical():
    """A member whose combine record raises mid-allreduce is quarantined
    and the combine re-places onto the fail-safe record; the collective
    completes and the result is bit-identical to the serial sum."""
    reg = _faulty_registry()
    agent = RuntimeAgent(registry=reg, manifest=default_manifest())
    try:
        comm = agent.comm_split(["xla", "jnp"])
        a, b = _x(seed=3), _x(seed=4)
        outs = comm.allreduce([a, b], op="sum")
        serial = np.asarray(a) + np.asarray(b)           # ewadd_ref math
        for o in outs:
            np.testing.assert_array_equal(np.asarray(o), serial)
        bad = next(r for r in reg.records("EWADD") if r.platform == "xla")
        assert agent.scheduler.is_failed(bad)
        # the quarantined record is skipped on the next collective: no
        # further _Boom, same result
        outs2 = comm.allreduce([a, b], op="sum")
        np.testing.assert_array_equal(np.asarray(outs2[0]), serial)
    finally:
        agent.finalize()


def test_member_compute_failure_replaces_shard():
    """A faulty member-compute record re-places that member's shard onto
    the fail-safe; the downstream reduce still sees every shard."""
    reg = _faulty_registry()
    agent = RuntimeAgent(registry=reg, manifest=default_manifest())
    try:
        comm = agent.comm_split(["xla", "jnp"])
        a, b = _x(seed=5), _x(seed=6)
        parts = comm.imap("PART", [(a,), (b,)])
        out = comm.reduce(parts, op="sum")
        np.testing.assert_array_equal(np.asarray(out),
                                      3.0 * np.asarray(a) + 3.0 * np.asarray(b))
        assert parts[0].attempts[0] == "xla"             # tried the member…
        assert parts[0].platform == "jnp"                # …landed on failsafe
    finally:
        agent.finalize()


def test_captured_collective_with_failing_member_completes():
    """Same quarantine path inside a graph capture."""
    reg = _faulty_registry()
    agent = RuntimeAgent(registry=reg, manifest=default_manifest())
    try:
        comm = agent.comm_split(["xla", "jnp"])
        a, b = _x(seed=7), _x(seed=8)
        with halo_graph(session=agent):
            parts = comm.imap("PART", [(a,), (b,)])
            red = comm.ireduce(parts, op="sum")
        np.testing.assert_array_equal(
            np.asarray(red.result(timeout=60)),
            3.0 * np.asarray(a) + 3.0 * np.asarray(b))
    finally:
        agent.finalize()


# -- group-aware scheduler ranking -------------------------------------------
def test_rank_platforms_orders_members_by_measured_latency():
    sched = CostModelScheduler(explore_every=0, tuning_db=False)
    fast = KernelRecord(alias="K", fn=lambda a: a, platform="jnp")
    slow = KernelRecord(alias="K", fn=lambda a: a, platform="xla")
    args = (jnp.ones((4, 4)),)
    from repro.core.scheduler import abstract_signature
    sig = abstract_signature(args)
    for rec, secs in [(fast, 1e-5), (slow, 1e-2)]:
        sched.observe(rec, sig, secs)            # warm-up discard
        sched.observe(rec, sig, secs)
    assert sched.rank_platforms("K", [slow, fast], args) == ["jnp", "xla"]
    # unmeasured members rank behind measured ones, keeping given order
    mystery = KernelRecord(alias="K", fn=lambda a: a, platform="pallas")
    assert sched.rank_platforms("K", [mystery, slow, fast], args) == \
        ["jnp", "xla", "pallas"]
    # quarantined members drop out entirely
    sched.mark_failed(fast)
    assert sched.rank_platforms("K", [slow, fast], args) == ["xla"]
