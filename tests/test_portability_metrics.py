"""Portability metrics (paper §VI-A) + data pipeline determinism."""
import numpy as np
import pytest

from repro.core.portability import (KernelReport, overhead_ratio,
                                    performance_penalty, portability_score)


def test_penalty_and_score_definitions():
    # paper: penalty = (T3_x - T3_base)/T3_base*100 ; Φ = T3_base/T3_x
    assert performance_penalty(2.0, 1.0) == 100.0
    assert performance_penalty(1.0, 1.0) == 0.0
    assert portability_score(1.0, 1.0) == 1.0
    assert portability_score(1.0, 100.0) == pytest.approx(0.01)
    assert overhead_ratio(1e-6, 1e-3) == pytest.approx(1e-3)
    assert overhead_ratio(1.0, 0.0) == 0.0


def test_kernel_report_roundtrip():
    r = KernelReport(kernel="MMM", device="cpu", t1_s=2e-6,
                     t3_baseline_s=1e-3, t3_halo_s=1e-3, t3_agnostic_s=1e-1)
    assert r.halo_score == pytest.approx(1.0)
    assert r.agnostic_score == pytest.approx(0.01)
    assert r.halo_gain == pytest.approx(100.0)
    assert r.overhead == pytest.approx(2e-6 / (2e-6 + 1e-3))
    assert "MMM,cpu" in r.csv()
    assert len(r.csv().split(",")) == len(r.csv_header().split(","))


def test_data_pipeline_deterministic_and_shifted():
    from repro.configs import get_config
    from repro.data import SyntheticLM
    cfg = get_config("h2o-danube-1.8b").reduced()
    pipe = SyntheticLM(cfg, seq_len=16, global_batch=2, seed=3)
    b1, b2 = pipe.batch(7), pipe.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])   # replayable
    b3 = pipe.batch(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token targets
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert b1["mask"][:, -1].sum() == 0


def test_data_pipeline_learnable_structure():
    """The Markov refresh makes token t+1 predictable ~half the time."""
    from repro.configs import get_config
    from repro.data import SyntheticLM
    cfg = get_config("h2o-danube-1.8b").reduced()
    pipe = SyntheticLM(cfg, seq_len=256, global_batch=4, seed=0)
    toks = pipe.batch(0)["tokens"]
    pred = (toks[:, :-1] * 7 + 1) % cfg.vocab_size
    frac = float((pred == toks[:, 1:]).mean())
    assert 0.3 < frac < 0.7
