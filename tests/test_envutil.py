"""Hardened HALO_* env parsing: malformed values warn and fall back
instead of blowing up init paths (doubly important for spawned workers,
which inherit whatever environment the launcher had)."""
import logging

import pytest

from repro.core.envutil import env_flag, env_float, env_int, env_path


def test_env_int_unset_and_empty(monkeypatch):
    monkeypatch.delenv("HALO_GRAPH_CACHE", raising=False)
    assert env_int("HALO_GRAPH_CACHE", 16) == 16
    monkeypatch.setenv("HALO_GRAPH_CACHE", "")
    assert env_int("HALO_GRAPH_CACHE", 16) == 16


def test_env_int_malformed_warns_and_falls_back(monkeypatch, caplog):
    monkeypatch.setenv("HALO_GRAPH_CACHE", "abc")
    with caplog.at_level(logging.WARNING, logger="repro.halo.env"):
        assert env_int("HALO_GRAPH_CACHE", 16) == 16
    assert any("HALO_GRAPH_CACHE" in r.message for r in caplog.records)


def test_env_int_valid(monkeypatch):
    monkeypatch.setenv("HALO_GRAPH_CACHE", "64")
    assert env_int("HALO_GRAPH_CACHE", 16) == 64


def test_env_float_empty_is_default_not_error(monkeypatch, caplog):
    """The motivating bug: HALO_HEARTBEAT_TIMEOUT="" used to raise
    ValueError inside HealthConfig.from_env."""
    monkeypatch.setenv("HALO_HEARTBEAT_TIMEOUT", "")
    with caplog.at_level(logging.WARNING, logger="repro.halo.env"):
        assert env_float("HALO_HEARTBEAT_TIMEOUT", 30.0) == 30.0
    # empty means "not configured": no warning noise
    assert not caplog.records


def test_env_float_malformed_warns(monkeypatch, caplog):
    monkeypatch.setenv("HALO_HEARTBEAT_TIMEOUT", "5s")
    with caplog.at_level(logging.WARNING, logger="repro.halo.env"):
        assert env_float("HALO_HEARTBEAT_TIMEOUT", 30.0) == 30.0
    assert any("HALO_HEARTBEAT_TIMEOUT" in r.message for r in caplog.records)


def test_env_float_valid_and_none_default(monkeypatch):
    monkeypatch.setenv("HALO_HEALTH_POLL", "2.5")
    assert env_float("HALO_HEALTH_POLL", None) == 2.5
    monkeypatch.delenv("HALO_HEALTH_POLL", raising=False)
    assert env_float("HALO_HEALTH_POLL", None) is None


def test_env_flag(monkeypatch):
    monkeypatch.delenv("HALO_FUSION", raising=False)
    assert env_flag("HALO_FUSION", default=True) is True
    assert env_flag("HALO_FUSION") is False
    monkeypatch.setenv("HALO_FUSION", "0")
    assert env_flag("HALO_FUSION", default=True) is False
    monkeypatch.setenv("HALO_FUSION", "1")
    assert env_flag("HALO_FUSION") is True
    monkeypatch.setenv("HALO_FUSION", "")
    assert env_flag("HALO_FUSION", default=True) is True


def test_env_path(monkeypatch, tmp_path):
    monkeypatch.delenv("HALO_TUNING_DB", raising=False)
    assert env_path("HALO_TUNING_DB") is None
    monkeypatch.setenv("HALO_TUNING_DB", "")
    assert env_path("HALO_TUNING_DB", "fallback") == "fallback"
    monkeypatch.setenv("HALO_TUNING_DB", str(tmp_path / "db.json"))
    assert env_path("HALO_TUNING_DB") == str(tmp_path / "db.json")


def test_health_config_survives_malformed_env(monkeypatch):
    """End to end through the real call site."""
    from repro.core.agents import HealthConfig
    monkeypatch.setenv("HALO_HEARTBEAT_TIMEOUT", "")
    monkeypatch.setenv("HALO_STRAGGLER_MULTIPLE", "fast")
    cfg = HealthConfig.from_env()
    assert cfg.heartbeat_timeout == 30.0
    assert cfg.straggler_multiple == 4.0


def test_graph_cache_size_survives_malformed_env(monkeypatch, caplog):
    """The fusion compile cache reads HALO_GRAPH_CACHE per trim; a typo'd
    value must degrade to the default bound, not fail the compile."""
    monkeypatch.setenv("HALO_GRAPH_CACHE", "abc")
    with caplog.at_level(logging.WARNING, logger="repro.halo.env"):
        assert env_int("HALO_GRAPH_CACHE", 16) == 16
    assert any("HALO_GRAPH_CACHE" in r.message for r in caplog.records)
