"""Serving substrate: ring-cache construction, engine generation, sampling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.models.transformer import ring_len
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import _to_ring, evict_slot, insert_slot, pad_caches


def test_ring_len_rules():
    cfg = get_config("h2o-danube-1.8b")          # SWA window 4096
    a = cfg.stages[0].pattern[0].attn
    assert ring_len(cfg, a, 32_768) == 4_096     # ring capped at window
    assert ring_len(cfg, a, 1_024) == 1_024      # short cache stays direct
    vlm = get_config("paligemma-3b")             # prefix must be retained
    assert ring_len(vlm, vlm.stages[0].pattern[0].attn, 32_768) == 32_768


def test_to_ring_slot_assignment(rng):
    """Ring slot j must hold position p with p % window == j."""
    w, s0 = 8, 13
    k = jnp.arange(s0, dtype=jnp.float32).reshape(1, 1, 1, s0, 1)
    ring = _to_ring(k, w)
    # retained positions: 5..12; slot = p % 8
    expect = np.zeros(w)
    for p in range(s0 - w, s0):
        expect[p % w] = p
    np.testing.assert_array_equal(np.asarray(ring[0, 0, 0, :, 0]), expect)


def test_to_ring_short_prefill_pads(rng):
    w, s0 = 8, 5
    k = jnp.ones((1, 1, 1, s0, 2))
    ring = _to_ring(k, w)
    assert ring.shape[3] == w
    np.testing.assert_array_equal(np.asarray(ring[0, 0, 0, s0:, :]), 0.0)


def test_insert_evict_slot_roundtrip(rng):
    """insert_slot writes a whole lane at the slot index; evict_slot zeroes
    it; untouched lanes stay untouched (DESIGN.md §6)."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    params = model.init(rng)
    full = model.init_cache(3, 16)
    _, one = model.prefill(
        params, {"tokens": jax.random.randint(rng, (1, 8), 0, cfg.vocab_size)})
    one = pad_caches(cfg, one, 16)
    filled = insert_slot(full, one, 1)
    for f, o in zip(jax.tree.leaves(filled), jax.tree.leaves(one)):
        np.testing.assert_array_equal(np.asarray(f[:, 1:2]),
                                      np.asarray(o.astype(f.dtype)))
        assert not np.asarray(f[:, 0]).any()      # neighbours untouched
        assert not np.asarray(f[:, 2]).any()
    cleared = evict_slot(filled, 1)
    assert all(not np.asarray(l).any() for l in jax.tree.leaves(cleared))


def test_engine_greedy_deterministic(rng):
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    params = model.init(rng)
    engine = ServeEngine(model, max_len=32)
    prompts = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)
    g1 = engine.generate(params, prompts, max_new=4)
    g2 = engine.generate(params, prompts, max_new=4)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def test_engine_temperature_sampling_varies(rng):
    cfg = get_config("mamba2-370m").reduced()
    model = build_model(cfg)
    params = model.init(rng)
    engine = ServeEngine(model, max_len=24)
    prompts = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)
    keys = jax.random.split(rng, 2)
    g1 = engine.generate(params, prompts, max_new=6, temperature=1.5,
                         key=keys[0])
    g2 = engine.generate(params, prompts, max_new=6, temperature=1.5,
                         key=keys[1])
    assert not np.array_equal(np.asarray(g1), np.asarray(g2))
    assert bool(jnp.all((g1 >= 0) & (g1 < cfg.vocab_size)))
