"""The unified ``repro.halo`` facade: every export resolves, facade names
are the subsystem objects (no forked behavior), and the one-call training
entry point works in both single-agent and device-group modes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import halo


def test_every_export_resolves():
    assert halo.__all__ == sorted(set(halo.__all__), key=halo.__all__.index)
    for name in halo.__all__:
        assert getattr(halo, name, None) is not None, name


def test_facade_names_are_the_subsystem_objects():
    from repro.core import c2mpi
    from repro.core.config import HaloConfig, configure, halo_config
    from repro.core.fusion import compile_graph
    from repro.core.graph import halo_graph
    from repro.distributed.remote import spawn_worker

    assert halo.dispatch is c2mpi.halo_dispatch
    assert halo.session is c2mpi.halo_session
    assert halo.initialize is c2mpi.MPIX_Initialize
    assert halo.claim is c2mpi.MPIX_Claim
    assert halo.allreduce is c2mpi.MPIX_Allreduce
    assert halo.graph is halo_graph
    assert halo.compile_graph is compile_graph
    assert halo.configure is configure
    assert halo.config is halo_config
    assert halo.HaloConfig is HaloConfig
    assert halo.spawn_worker is spawn_worker


def test_dispatch_and_collectives_through_facade():
    halo.initialize()
    out = halo.dispatch("EWADD", jnp.ones(8), jnp.ones(8))
    np.testing.assert_array_equal(np.asarray(out), np.full(8, 2.0))
    comm = halo.comm_split(["xla", "jnp"])
    parts = halo.scatter(jnp.arange(8, dtype=jnp.float32), comm)
    assert [p.shape[0] for p in parts] == [4, 4]
    total = halo.allreduce([p.sum() for p in parts], comm)
    assert [float(t) for t in total] == [28.0, 28.0]
    comm.free()


def test_train_entry_point_single_vs_group_bit_identical():
    """halo.train at equal global batch: a 2-member group reproduces the
    1-member loss history bit-for-bit (DESIGN.md §15)."""
    kw = dict(steps=2, seq_len=32, batch=8, reduced=True, microbatches=2,
              log_every=1)
    _, h1 = halo.train("h2o-danube-1.8b", **kw)
    _, h2 = halo.train("h2o-danube-1.8b", comm=2, **kw)
    assert len(h1) == 2 and h1 == h2


def test_train_rejects_bad_microbatches():
    with pytest.raises(ValueError, match="multiple"):
        halo.train("h2o-danube-1.8b", reduced=True, comm=2, microbatches=3,
                   steps=1)
