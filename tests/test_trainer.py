"""Trainer: convergence, microbatch equivalence, compression, resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim.adamw import adamw_init, adamw_update, global_norm
from repro.optim.compression import compress_gradients, decompress_gradients
from repro.optim.schedule import linear_warmup_cosine
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import TrainHyper, Trainer, TrainState, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    pipe = SyntheticLM(cfg, seq_len=32, global_batch=8)
    data = lambda s: {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
    return cfg, model, data


def test_loss_decreases(setup, rng):
    cfg, model, data = setup
    hp = TrainHyper(base_lr=1e-2, warmup_steps=5, total_steps=40)
    tr = Trainer(model=model, hp=hp, log_every=10)
    state = tr.init_state(rng)
    state, hist = tr.run(state, data, steps=40)
    assert hist[-1][1] < hist[0][1] - 0.3, hist


def test_microbatch_equivalence(setup, rng):
    """microbatches=2 computes the same averaged gradients (± numerics)."""
    cfg, model, data = setup
    batch = data(0)
    s1 = TrainState(params=model.init(rng), opt=adamw_init(model.init(rng)))
    s2 = TrainState(params=s1.params, opt=s1.opt)
    st1, m1 = jax.jit(make_train_step(model, TrainHyper(microbatches=1)))(s1, batch)
    st2, m2 = jax.jit(make_train_step(model, TrainHyper(microbatches=2)))(s2, batch)
    # parameters after one step agree closely
    f1, f2 = jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)
    for a, b in zip(f1, f2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-4)


def test_gradient_compression_error_feedback(rng):
    g = {"w": jax.random.normal(rng, (300,)) * 0.01}
    q, scales, err = compress_gradients(g)
    deq = decompress_gradients(q, scales, g)
    # int8 block quantization: relative error small; error feedback captures
    # exactly the residual
    np.testing.assert_allclose(deq["w"], g["w"], atol=2e-4)
    np.testing.assert_allclose(np.asarray(err["w"]),
                               np.asarray(g["w"] - deq["w"]), atol=1e-7)
    # second step: residual is added before quantization (bias correction)
    q2, s2, err2 = compress_gradients(g, err)
    deq2 = decompress_gradients(q2, s2, g)
    total = np.asarray(deq2["w"]) + np.asarray(err2["w"])
    np.testing.assert_allclose(total, np.asarray(g["w"]) + np.asarray(err["w"]),
                               atol=1e-6)


def test_compressed_training_still_converges(setup, rng):
    cfg, model, data = setup
    hp = TrainHyper(base_lr=1e-2, warmup_steps=5, total_steps=30,
                    compress_grads=True)
    tr = Trainer(model=model, hp=hp, log_every=10)
    state = tr.init_state(rng)
    state, hist = tr.run(state, data, steps=30)
    assert hist[-1][1] < hist[0][1] - 0.2, hist


def test_resume_from_checkpoint(setup, rng, tmp_path):
    cfg, model, data = setup
    hp = TrainHyper(base_lr=3e-3, warmup_steps=5, total_steps=30)
    tr = Trainer(model=model, hp=hp, ckpt=CheckpointManager(str(tmp_path)),
                 log_every=5, ckpt_every=10)
    state = tr.init_state(rng)
    state, _ = tr.run(state, data, steps=12)
    tr.ckpt.wait()
    # fresh trainer resumes from the saved step
    tr2 = Trainer(model=model, hp=hp, ckpt=CheckpointManager(str(tmp_path)),
                  log_every=5)
    restored, step = tr2.restore_or_init(rng)
    assert step >= 10
    np.testing.assert_allclose(
        np.asarray(restored.opt.step), np.asarray(state.opt.step) - 1,
        atol=2)   # resumed at the last checkpoint boundary


def test_adamw_decreases_quadratic(rng):
    w = {"x": jnp.ones(4) * 5.0}
    opt = adamw_init(w)
    for _ in range(200):
        g = jax.tree.map(lambda p: 2 * p, w)       # d/dx of x²
        w, opt, m = adamw_update(w, g, opt, lr=0.1, weight_decay=0.0)
    assert float(jnp.abs(w["x"]).max()) < 0.5
    assert m["grad_norm"].shape == ()


def test_schedule_shapes():
    lr0 = linear_warmup_cosine(jnp.asarray(0), base_lr=1e-3, warmup_steps=10,
                               total_steps=100)
    lr5 = linear_warmup_cosine(jnp.asarray(5), base_lr=1e-3, warmup_steps=10,
                               total_steps=100)
    lr100 = linear_warmup_cosine(jnp.asarray(100), base_lr=1e-3,
                                 warmup_steps=10, total_steps=100)
    assert float(lr0) == 0.0
    assert 0 < float(lr5) < 1e-3
    assert float(lr100) == pytest.approx(1e-4, rel=1e-2)


def test_global_norm():
    t = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    assert float(global_norm(t)) == pytest.approx((4 * 9 + 9 * 16) ** 0.5)
