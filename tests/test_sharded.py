"""Distributed-correctness tests on an 8-virtual-device CPU mesh.

Run in subprocesses because the host device count must be forced before
first jax initialization (and only for these tests)."""
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow          # 8-virtual-device subprocess suite (~5 min)

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
       "JAX_PLATFORMS": "cpu"}


def run_sub(code: str, timeout=600):
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=ENV,
                         cwd="/root/repo", timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_mesh
from repro.models import build_model
mesh = make_mesh((2, 2), ("data", "model"))
"""


def test_sharded_loss_equals_unsharded():
    """The same params/batch give identical loss on 1 device and on a 2×2
    mesh (the HALO portability property for the distribution substrate)."""
    run_sub(HEADER + """
cfg = get_config("h2o-danube-1.8b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab_size)}
loss_1d, _ = jax.jit(model.loss_fn)(params, batch)
with mesh_context(mesh):
    loss_sh, _ = jax.jit(model.loss_fn)(params, batch)
np.testing.assert_allclose(np.asarray(loss_1d), np.asarray(loss_sh), rtol=2e-4)
print("SHARDED_LOSS_OK", float(loss_1d), float(loss_sh))
""")


def test_moe_a2a_equals_local():
    """Expert-parallel a2a MoE == single-shard MoE on identical inputs."""
    run_sub(HEADER + """
import dataclasses
from repro.configs.base import MoEConfig
from repro.models.moe import moe_layer, _moe_local
key = jax.random.PRNGKey(0)
m = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, capacity_factor=8.0)
d = 32
ks = jax.random.split(key, 5)
p = {"router": jax.random.normal(ks[0], (d, 8)),
     "we_g": jax.random.normal(ks[1], (8, d, 16)) * 0.2,
     "we_u": jax.random.normal(ks[2], (8, d, 16)) * 0.2,
     "we_d": jax.random.normal(ks[3], (8, 16, d)) * 0.2}
x = jax.random.normal(ks[4], (2, 8, d))   # 16 tokens over 4 shards
y_loc, aux_loc = _moe_local(p, x.reshape(-1, d), m, "swiglu")
with mesh_context(mesh):
    y_sh, aux_sh = jax.jit(lambda p, x: moe_layer(p, x, m, "swiglu"))(p, x)
np.testing.assert_allclose(np.asarray(y_sh).reshape(-1, d), np.asarray(y_loc),
                           rtol=2e-3, atol=2e-3)
print("MOE_A2A_OK")
""")


def test_moe_replicated_decode_equals_local():
    """Decode-mode (token-replicated) expert parallelism == local MoE."""
    run_sub(HEADER + """
import dataclasses
from repro.configs.base import MoEConfig
from repro.models.moe import moe_layer, _moe_local
key = jax.random.PRNGKey(0)
m = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, capacity_factor=8.0)
d = 32
ks = jax.random.split(key, 5)
p = {"router": jax.random.normal(ks[0], (d, 8)),
     "we_g": jax.random.normal(ks[1], (8, d, 16)) * 0.2,
     "we_u": jax.random.normal(ks[2], (8, d, 16)) * 0.2,
     "we_d": jax.random.normal(ks[3], (8, 16, d)) * 0.2}
x = jax.random.normal(ks[4], (2, 1, d))   # B=2, S=1: replicated mode
y_loc, _ = _moe_local(p, x.reshape(-1, d), m, "swiglu")
with mesh_context(mesh):
    y_sh, _ = jax.jit(lambda p, x: moe_layer(p, x, m, "swiglu"))(p, x)
np.testing.assert_allclose(np.asarray(y_sh).reshape(-1, d), np.asarray(y_loc),
                           rtol=2e-3, atol=2e-3)
print("MOE_REPLICATED_OK")
""")


def test_sp_rules_match_default():
    """Sequence-parallel residual sharding is numerically transparent."""
    run_sub(HEADER + """
from repro.distributed.sharding import sp_rules
cfg = get_config("h2o-danube-1.8b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab_size)}
with mesh_context(mesh):
    base, _ = jax.jit(model.loss_fn)(params, batch)
with mesh_context(mesh, sp_rules()):
    sp, _ = jax.jit(model.loss_fn)(params, batch)
np.testing.assert_allclose(np.asarray(base), np.asarray(sp), rtol=2e-4)
print("SP_OK")
""")


def test_train_step_sharded_runs():
    """One sharded train step end-to-end (grads + AdamW on the mesh)."""
    run_sub(HEADER + """
from repro.train.trainer import TrainHyper, TrainState, make_train_step
from repro.optim.adamw import adamw_init
cfg = get_config("moonshot-v1-16b-a3b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
state = TrainState(params=params, opt=adamw_init(params))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab_size)}
with mesh_context(mesh):
    step = jax.jit(make_train_step(model, TrainHyper()))
    state, metrics = step(state, batch)
assert np.isfinite(float(metrics["loss"]))
print("SHARDED_TRAIN_OK", float(metrics["loss"]))
""")


def test_int8_a2a_dispatch_close_to_exact():
    """int8 wire-format dispatch ≈ bf16 dispatch (per-token absmax quant)."""
    run_sub(HEADER + """
import dataclasses
from repro.configs.base import MoEConfig
from repro.models.moe import moe_layer, _moe_local
key = jax.random.PRNGKey(0)
m = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, capacity_factor=8.0,
              a2a_precision="int8")
d = 32
ks = jax.random.split(key, 5)
p = {"router": jax.random.normal(ks[0], (d, 8)),
     "we_g": jax.random.normal(ks[1], (8, d, 16)) * 0.2,
     "we_u": jax.random.normal(ks[2], (8, d, 16)) * 0.2,
     "we_d": jax.random.normal(ks[3], (8, 16, d)) * 0.2}
x = jax.random.normal(ks[4], (2, 8, d))
y_ref, _ = _moe_local(p, x.reshape(-1, d),
                      dataclasses.replace(m, a2a_precision="bf16"), "swiglu")
with mesh_context(mesh):
    y_q, _ = jax.jit(lambda p, x: moe_layer(p, x, m, "swiglu"))(p, x)
rel = float(jnp.max(jnp.abs(y_q.reshape(-1, d) - y_ref))) / \
      float(jnp.max(jnp.abs(y_ref)))
assert rel < 0.05, rel
print("INT8_OK", rel)
""")
