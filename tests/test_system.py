"""End-to-end behaviour tests for the whole system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (MPIX_Claim, MPIX_Finalize, MPIX_Initialize, MPIX_Recv,
                        MPIX_Send, halo_session)
from repro.data import SyntheticLM
from repro.models import build_model
from repro.serve.engine import RequestQueue, ServeEngine
from repro.train.trainer import TrainHyper, Trainer


def test_paper_template_runs_all_eight_subroutines(rng):
    """The Table-V host template executes every evaluated subroutine with a
    unified control flow — the paper's core claim."""
    from repro.kernels.spmm import dense_to_bell, random_block_sparse
    MPIX_Initialize()
    n = 128
    k1, k2 = jax.random.split(rng)
    a = jax.random.normal(k1, (n, n))
    b = jax.random.normal(k2, (n, n)) + 3.0
    x = jax.random.normal(k1, (n,))
    sp = random_block_sparse(k2, n, n, 32, 128, 0.5)
    vals, idx = dense_to_bell(sp, 32, 128)
    sig = jax.random.normal(k1, (2048,))
    taps = jax.random.normal(k2, (9,))
    jobs = {"MMM": (a, b), "EWMM": (a, b), "EWMD": (a, b), "MVM": (a, x),
            "VDP": (x, x), "JS": (a + n * jnp.eye(n), jnp.zeros(n), x),
            "1DCONV": (sig, taps), "SMMM": (vals, idx, b),
            "FFT": (sig[:1024],), "SORT": (x,),
            "HIST": (jax.nn.sigmoid(sig),)}
    for alias, args in jobs.items():
        cr = MPIX_Claim(alias)
        MPIX_Send(args, cr)
        out = MPIX_Recv(cr)
        leaves = jax.tree.leaves(out)
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves), alias
    MPIX_Finalize()


def test_train_then_serve_roundtrip(rng):
    """Train a reduced model until loss drops, then serve greedy decodes and
    check they match the model's own teacher-forced predictions."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    hp = TrainHyper(base_lr=1e-2, warmup_steps=5, total_steps=30)
    trainer = Trainer(model=model, hp=hp, log_every=10)
    state = trainer.init_state(rng)
    pipe = SyntheticLM(cfg, seq_len=32, global_batch=8)
    state, hist = trainer.run(
        state, lambda s: {k: jnp.asarray(v) for k, v in pipe.batch(s).items()},
        steps=30)
    assert hist[-1][1] < hist[0][1]

    engine = ServeEngine(model, max_len=48)
    prompts = jnp.asarray(pipe.batch(99)["tokens"][:2, :16])
    gen = engine.generate(state.params, prompts, max_new=4)
    assert gen.shape == (2, 4)
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab_size)))
    # greedy decode step 0 matches argmax of teacher-forced logits
    lg, _ = model.prefill(state.params, {"tokens": prompts})
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lg, -1)),
                                  np.asarray(gen[:, 0]))


def test_request_queue_batched_serving(rng):
    cfg = get_config("mamba2-370m").reduced()
    model = build_model(cfg)
    params = model.init(rng)
    engine = ServeEngine(model, max_len=32)
    q = RequestQueue(engine, params, batch_size=2, prompt_len=8)
    futs = [q.submit([1, 2, 3, 4, 5, 6, 7, 8], max_new=3) for _ in range(3)]
    done = []
    while q.pending():
        done.extend(q.flush())
    assert sorted(r.uid for r in done) == sorted(f.uid for f in futs)
    assert all(len(r.result) == 3 for r in done)
    # the futures observe the same results the flush reported
    assert [f.result(timeout=5) for f in futs] == \
        [r.result for r in sorted(done, key=lambda r: r.uid)]


def test_request_queue_background_drain_partial_batch(rng):
    """Continuous batching: the drain loop flushes a partial batch once the
    oldest submission exceeds max_delay — no flush() calls from the client."""
    cfg = get_config("mamba2-370m").reduced()
    model = build_model(cfg)
    params = model.init(rng)
    engine = ServeEngine(model, max_len=32)
    q = RequestQueue(engine, params, batch_size=4, prompt_len=8,
                     max_delay=0.02)
    with q:
        futs = [q.submit([1, 2, 3, 4, 5, 6, 7, 8], max_new=2)
                for _ in range(3)]                     # never fills the batch
        results = [f.result(timeout=120) for f in futs]
    assert all(len(r) == 2 for r in results)
    assert q.pending() == 0


def test_halo_dispatch_inside_jit_zero_step_overhead(rng):
    """Trace-time dispatch: after compilation the HALO layer adds nothing to
    the step (selection happened while tracing)."""
    session = halo_session()
    a = jax.random.normal(rng, (64, 64))

    @jax.jit
    def step(a):
        return session.dispatch("MMM", a, a)

    step(a)                       # compile
    session.reset_t1()
    for _ in range(3):
        jax.block_until_ready(step(a))
    assert session._t1_calls == 0   # no dispatch work per executed step
