"""Dry-run machinery: one real (cheap) cell in a subprocess + unit tests of
the collective parser and cost correction."""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.launch.dryrun import (_tensor_bytes, collective_link_bytes,
                                 parse_collectives)

pytestmark = pytest.mark.slow          # subprocess lowering suite (~8 min)

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}


def test_tensor_bytes():
    assert _tensor_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert _tensor_bytes("(bf16[8,8], f32[4])") == 8 * 8 * 2 + 16
    assert _tensor_bytes("pred[10]") == 10


def test_parse_collectives_counts_and_normalizes():
    hlo = """
  %p0 = bf16[64,64]{1,0} parameter(0)
  %dot.1 = f32[64,64]{1,0} dot(%p0, %p0)
  %all-reduce.1 = f32[64,64]{1,0} all-reduce(%dot.1), to_apply=%add_promoted
  %ag.2 = bf16[64,64]{1,0} all-gather(%p0), dimensions={0}
"""
    colls = parse_collectives(hlo)
    assert colls["all-reduce"]["count"] == 1
    # promoted f32 all-reduce counted at bf16 width
    assert colls["all-reduce"]["bytes"] == 64 * 64 * 2
    assert colls["all-reduce"]["bytes_raw"] == 64 * 64 * 4
    assert colls["all-gather"]["bytes"] == 64 * 64 * 2
    total = collective_link_bytes(colls)
    assert total == 2 * 64 * 64 * 2 + 64 * 64 * 2   # AR×2 + AG×1


@pytest.mark.slow
def test_one_cell_end_to_end(tmp_path):
    """Compile mamba2 decode on the 256-chip mesh inside a subprocess; checks
    the full lower→compile→analyze→record pipeline."""
    code = textwrap.dedent(f"""
        from repro.launch.dryrun import run_cell
        from pathlib import Path
        rec = run_cell("mamba2-370m", "decode_32k", "single",
                       Path({str(tmp_path)!r}))
        assert rec["status"] == "ok", rec.get("error")
        assert rec["chips"] == 256
        assert rec["cost"]["flops"] > 0
        assert "cost_corrected" in rec
        print("CELL_OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=ENV, cwd="/root/repo", timeout=580)
    assert "CELL_OK" in out.stdout, out.stderr[-3000:]
    rec = json.loads(next(tmp_path.glob("*.json")).read_text())
    assert rec["arch"] == "mamba2-370m"
    assert rec["memory"]["temp_bytes"] > 0


def test_long_500k_skip_is_recorded(tmp_path):
    from pathlib import Path
    from repro.launch.dryrun import run_cell
    rec = run_cell("gemma-7b", "long_500k", "single", Path(str(tmp_path)),
                   verbose=False)
    assert rec["status"] == "skipped"
    assert "sub-quadratic" in rec["reason"]


def test_roofline_math():
    from benchmarks.roofline import roofline_row
    rec = {"status": "ok", "chips": 256,
           "cost": {"flops": 197e12, "bytes_accessed": 819e9},
           "collective_link_bytes": 50e9,
           "model_flops": 197e12 * 256,
           "memory": {"argument_bytes": 0, "temp_bytes": 0},
           "arch": "x", "shape": "y", "mesh": "single"}
    row = roofline_row(rec)
    assert row["compute_s"] == pytest.approx(1.0)
    assert row["memory_s"] == pytest.approx(1.0)
    assert row["collective_s"] == pytest.approx(1.0)
    assert row["useful_flops_frac"] == pytest.approx(1.0)
