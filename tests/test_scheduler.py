"""Cost-model scheduler: analytic estimates override static platform
preference, measured latencies override analytic estimates, and the autotune
cache persists across scheduler instances (DESIGN.md §4)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CostModelScheduler, KernelRecord, KernelRegistry,
                        RuntimeAgent, abstract_signature, default_manifest)


def _registry(cost_fast=None, cost_slow=None):
    """Two MMM-style records: 'fast' on the statically *dispreferred* jnp
    platform, 'slow' on the statically preferred xla platform."""
    reg = KernelRegistry()
    reg.register(KernelRecord(alias="K", fn=lambda a: a + 1.0, platform="xla",
                              priority=10, cost_model=cost_slow))
    reg.register(KernelRecord(alias="K", fn=lambda a: a + 2.0, platform="jnp",
                              priority=0, cost_model=cost_fast,
                              is_failsafe=True))
    return reg


def test_cost_model_overrides_static_platform_preference():
    reg = _registry(cost_fast=lambda a: 1e-6, cost_slow=lambda a: 1e-3)
    agent = RuntimeAgent(registry=reg, manifest=default_manifest(),
                         scheduler=CostModelScheduler())
    cr = agent.claim("K")
    agent.send((jnp.zeros(4),), cr)
    out = agent.recv(cr)
    np.testing.assert_allclose(np.asarray(out), 2.0)   # jnp record won
    # without the scheduler, static preference picks the xla record
    agent_static = RuntimeAgent(registry=reg, manifest=default_manifest(),
                                scheduler=False)
    cr2 = agent_static.claim("K")
    agent_static.send((jnp.zeros(4),), cr2)
    np.testing.assert_allclose(np.asarray(agent_static.recv(cr2)), 1.0)


def test_records_without_estimates_fall_back_to_static_order():
    reg = _registry()                                   # no cost models
    agent = RuntimeAgent(registry=reg, manifest=default_manifest())
    cr = agent.claim("K")
    agent.send((jnp.zeros(4),), cr)
    np.testing.assert_allclose(np.asarray(agent.recv(cr)), 1.0)  # xla record


def test_measured_latency_overrides_cost_model():
    """A wrong analytic model is corrected by observed latencies."""
    # the model claims xla is faster ...
    reg = _registry(cost_fast=lambda a: 1e-3, cost_slow=lambda a: 1e-6)
    xla_rec, jnp_rec = reg.records("K")
    sched = CostModelScheduler()
    args = (jnp.zeros(4),)
    sig = abstract_signature(args)
    # ... but measurements say otherwise (first sample per key is warmup)
    for _ in range(3):
        sched.observe(xla_rec, sig, 5e-3)
        sched.observe(jnp_rec, sig, 1e-5)
    assert sched.measured(xla_rec, sig) == pytest.approx(5e-3)
    agent = RuntimeAgent(registry=reg, manifest=default_manifest(),
                         scheduler=sched)
    cr = agent.claim("K")
    agent.send(args, cr)
    np.testing.assert_allclose(np.asarray(agent.recv(cr)), 2.0)  # jnp record


def test_warmup_sample_is_discarded():
    rec = KernelRecord(alias="K", fn=lambda a: a, platform="xla")
    sched = CostModelScheduler()
    sig = abstract_signature((jnp.zeros(4),))
    sched.observe(rec, sig, 123.0)               # compile-tainted
    assert sched.measured(rec, sig) is None
    sched.observe(rec, sig, 1.0)
    assert sched.measured(rec, sig) == pytest.approx(1.0)
    sched.observe(rec, sig, 2.0)                 # EMA moves toward 2
    assert 1.0 < sched.measured(rec, sig) < 2.0


def test_same_platform_replicas_have_separate_measurements():
    """Two records on one alias+platform (registry replicas) must not share
    a latency table entry."""
    v1 = KernelRecord(alias="K", fn=lambda a: a, platform="pallas", priority=1)
    v2 = KernelRecord(alias="K", fn=lambda a: a, platform="pallas", priority=2)
    sched = CostModelScheduler()
    sig = abstract_signature((jnp.zeros(4),))
    for _ in range(2):
        sched.observe(v1, sig, 1e-3)
    assert sched.measured(v1, sig) == pytest.approx(1e-3)
    assert sched.measured(v2, sig) is None


def test_autotune_cache_persists_across_instances(tmp_path):
    rec = KernelRecord(alias="K", fn=lambda a: a, platform="pallas")
    path = tmp_path / "autotune.json"
    sched = CostModelScheduler(cache_path=path)
    sig = abstract_signature((jnp.zeros((8, 8)),))
    sched.observe(rec, sig, 1.0)                 # warmup
    sched.observe(rec, sig, 2e-4)
    sched.save()
    assert path.exists()
    warm = CostModelScheduler(cache_path=path)
    assert warm.measured(rec, sig) == pytest.approx(2e-4)
    # the next process's first sample is compile-tainted: still discarded,
    # so a warm-loaded EMA is never poisoned by jit time
    warm.observe(rec, sig, 50.0)
    assert warm.measured(rec, sig) == pytest.approx(2e-4)
    warm.observe(rec, sig, 2e-4)
    assert warm.measured(rec, sig) == pytest.approx(2e-4)


def test_corrupt_autotune_cache_starts_cold(tmp_path):
    path = tmp_path / "autotune.json"
    path.write_text('{"some|key": 0.5}')         # valid JSON, wrong shape
    sched = CostModelScheduler(cache_path=path)  # must not raise
    rec = KernelRecord(alias="K", fn=lambda a: a, platform="xla")
    assert sched.measured(rec, abstract_signature((jnp.zeros(2),))) is None


def test_runtime_feedback_populates_measurements():
    """End-to-end: repeated DRPC sends feed the scheduler's table."""
    reg = KernelRegistry()
    rec = reg.register(KernelRecord(alias="ADD", fn=lambda a: a + 1.0,
                                    platform="jnp", is_failsafe=True))
    sched = CostModelScheduler()
    agent = RuntimeAgent(registry=reg, manifest=default_manifest(),
                         scheduler=sched)
    cr = agent.claim("ADD")
    args = (jnp.zeros(16),)
    for _ in range(3):
        agent.send(args, cr)
        agent.recv(cr)
    est = sched.measured(rec, abstract_signature(args))
    assert est is not None and est > 0.0


def test_exploration_policy_is_injectable_and_deterministic():
    """The 1-in-N exploration is seeded via (explore_every, explore_offset):
    two schedulers built with the same knobs make identical choices, and the
    exploring call index is exactly pinned — no instance-global call history
    or module state involved."""
    reg = _registry(cost_fast=None, cost_slow=lambda a: 1e-6)  # jnp unmeasured
    xla_rec, jnp_rec = reg.records("K")
    args = (jnp.zeros(4),)

    def choices(sched, n=6):
        return [sched.choose("K", [xla_rec, jnp_rec], args, explore=True)
                for _ in range(n)]

    a = CostModelScheduler(explore_every=3)
    b = CostModelScheduler(explore_every=3)
    assert choices(a) == choices(b)                      # deterministic
    assert choices(CostModelScheduler(explore_every=3)) == [
        xla_rec, xla_rec, jnp_rec, xla_rec, xla_rec, jnp_rec]
    # offset shifts which call explores: offset = N-1 → the first call
    assert choices(CostModelScheduler(explore_every=3, explore_offset=2),
                   n=3) == [jnp_rec, xla_rec, xla_rec]
    # explore_every=0/None disables exploration entirely
    assert choices(CostModelScheduler(explore_every=0)) == [xla_rec] * 6


def test_runtime_agent_accepts_injected_exploration():
    """End-to-end determinism: an agent wired with explore_every=0 never
    routes a DRPC send to an unmeasured record."""
    reg = _registry(cost_fast=None, cost_slow=lambda a: 1e-6)
    agent = RuntimeAgent(registry=reg, manifest=default_manifest(),
                         scheduler=CostModelScheduler(explore_every=0))
    cr = agent.claim("K")
    for _ in range(40):                   # > default explore_every
        agent.send((jnp.zeros(4),), cr)
        out = agent.recv(cr)
    np.testing.assert_allclose(np.asarray(out), 1.0)     # always xla


def test_mark_failed_quarantines_until_cleared():
    reg = _registry(cost_fast=lambda a: 1e-6, cost_slow=lambda a: 1e-3)
    xla_rec, jnp_rec = reg.records("K")
    sched = CostModelScheduler()
    args = (jnp.zeros(4),)
    sched.mark_failed(jnp_rec)
    assert sched.is_failed(jnp_rec) and not sched.is_failed(xla_rec)
    # the runtime agent's selection skips quarantined records
    agent = RuntimeAgent(registry=reg, manifest=default_manifest(),
                         scheduler=sched)
    cr = agent.claim("K")
    agent.send(args, cr)
    np.testing.assert_allclose(np.asarray(agent.recv(cr)), 1.0)  # xla record
    sched.clear_failures()
    assert not sched.is_failed(jnp_rec)


def test_place_transfer_penalty_and_backlog():
    """Graph placement scoring: transfer penalty binds chains to the parent
    substrate; backlog spreads independent work to an idle substrate."""
    reg = _registry(cost_fast=lambda a: 0.9e-4, cost_slow=lambda a: 1.0e-4)
    xla_rec, jnp_rec = reg.records("K")
    sched = CostModelScheduler()
    args = (jnp.zeros((64, 64)),)
    cands = [xla_rec, jnp_rec]
    # independent node: jnp is cheapest outright
    assert sched.place("K", cands, args) is jnp_rec
    # same node downstream of an xla parent: the hop costs more than 10 µs
    assert sched.place("K", cands, args,
                       parent_platforms=["xla"],
                       payload_bytes=64 * 64 * 4) is xla_rec
    # heavy xla backlog pushes an independent node onto jnp
    assert sched.place("K", cands, args,
                       backlog={"xla": 1.0}) is jnp_rec
    # no candidate has an estimate → None (caller falls back to static)
    bare = KernelRecord(alias="K", fn=lambda a: a, platform="xla")
    assert sched.place("K", [bare], args) is None


def test_abstract_signature_shapes_and_dtypes():
    import jax
    sig = abstract_signature((jnp.zeros((2, 3), jnp.float32),
                              jax.ShapeDtypeStruct((4,), jnp.int32), 7))
    assert sig == (((2, 3), "float32"), ((4,), "int32"), ((), "int"))
