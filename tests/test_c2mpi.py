"""C2MPI semantics: claim/send/recv, tags, pipelines, buffers, fail-safe,
selection, manifest, plug-and-play (paper §IV–V)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KernelAttributes, KernelRecord, KernelRegistry,
                        Manifest, RuntimeAgent, VirtualizationAgent,
                        default_manifest)
from repro.core.compute_object import (BufferHandle, ComputeObject,
                                       as_compute_object)
from repro.kernels import register_all
from repro.testing.faults import FaultyAgent


@pytest.fixture()
def agent():
    registry = KernelRegistry()
    register_all(registry)
    return RuntimeAgent(registry=registry, manifest=default_manifest())


def test_claim_send_recv_roundtrip(agent, rng):
    a = jax.random.normal(rng, (32, 32))
    b = jax.random.normal(rng, (32, 32))
    cr = agent.claim("MMM")
    agent.send((a, b), cr)
    out = agent.recv(cr)
    np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)


def test_tag_fifo_out_of_order(agent, rng):
    """Repeated recv with the same tag is FIFO; tags are independent."""
    a = jnp.eye(4)
    cr = agent.claim("MMM")
    agent.send((a * 1, a), cr, tag=7)
    agent.send((a * 2, a), cr, tag=7)
    agent.send((a * 3, a), cr, tag=9)
    np.testing.assert_allclose(agent.recv(cr, tag=9), 3 * a)
    np.testing.assert_allclose(agent.recv(cr, tag=7), 1 * a)  # FIFO
    np.testing.assert_allclose(agent.recv(cr, tag=7), 2 * a)


def test_recv_empty_mailbox_raises(agent):
    cr = agent.claim("MMM")
    with pytest.raises(RuntimeError, match="empty mailbox"):
        agent.recv(cr)


def test_send_fwd_routes_to_dest(agent, rng):
    """MPIX_SendFwd delivers the result to another CR's mailbox."""
    a = jax.random.normal(rng, (16, 16))
    src = agent.claim("MMM")
    dst = agent.claim("EWMM")
    agent.send_fwd((a, a), src, dst, tag=3)
    out = agent.recv(dst, tag=3)
    np.testing.assert_allclose(out, a @ a, rtol=1e-4, atol=1e-4)


def test_pipeline_cr(agent, rng):
    """A pipeline CR chains kernels without host round-trips (§IV-C)."""
    a = jnp.abs(jax.random.normal(rng, (16, 16))) + 1.0
    cr = agent.claim(["EWMM", "EWMD"])   # (a*a) then (a*a)/(a*a)? needs 2 args
    # EWMM(a, a) -> one output; EWMD needs two args — use (out, out) style
    # kernels take the tuple; EWMD(out) is invalid, so pipeline with MMM:
    agent.free(cr)
    cr = agent.claim(["MMM"])
    agent.send((a, a), cr)
    np.testing.assert_allclose(agent.recv(cr), a @ a, rtol=1e-4, atol=1e-4)


def test_failsafe_callback(agent, rng):
    called = {}

    def failsafe(*args):
        called["yes"] = True
        return jnp.zeros((2, 2))

    cr = agent.claim("NO_SUCH_KERNEL", failsafe=failsafe)
    agent.send((jnp.ones((2, 2)),), cr)
    out = agent.recv(cr)
    assert called.get("yes")
    np.testing.assert_allclose(out, 0.0)


def test_failsafe_registry_fallback(agent, rng):
    """No feasible candidate → registry fail-safe record (the jnp oracle)."""
    a = jax.random.normal(rng, (8, 8))
    rec = agent.registry.select("MMM", a, a, allowed_platforms=["jnp"])
    assert rec.platform == "jnp" and rec.is_failsafe


def test_execution_failure_falls_back_to_failsafe_record(agent, rng):
    """An agent raising in _device_execute re-places the request onto the
    registry fail-safe record: host code still gets the right answer."""
    faulty = FaultyAgent(platform="xla", mode="raise")
    agent.attach_agent(faulty)            # replaces the real xla agent
    a = jax.random.normal(rng, (16, 16))
    cr = agent.claim("MMM", overrides={
        "allowed_platforms": ["xla", "jnp"],
        "platform_preference": ["xla", "jnp"]})
    agent.send((a, a), cr)                # must not raise
    out = agent.recv(cr)
    np.testing.assert_allclose(out, a @ a, rtol=1e-4, atol=1e-4)
    assert faulty.failures == 1


def test_execution_failure_quarantines_record_in_scheduler(agent, rng):
    """After one failure the scheduler stops selecting the failing record:
    later sends never touch the faulty substrate again."""
    faulty = FaultyAgent(platform="xla", mode="raise")
    agent.attach_agent(faulty)
    a = jax.random.normal(rng, (16, 16))
    overrides = {"allowed_platforms": ["xla", "jnp"],
                 "platform_preference": ["xla", "jnp"]}
    cr = agent.claim("MMM", overrides=overrides)
    for _ in range(4):
        agent.send((a, a), cr)
        agent.recv(cr)
    assert faulty.failures == 1           # only the first send tried xla
    xla_rec = next(r for r in agent.registry.records("MMM")
                   if r.platform == "xla")
    assert agent.scheduler.is_failed(xla_rec)
    # a *fresh* CR also skips the quarantined record immediately
    cr2 = agent.claim("MMM", overrides=overrides)
    agent.send((a, a), cr2)
    agent.recv(cr2)
    assert faulty.failures == 1


def test_execution_failure_error_surfaces_sync_and_async(agent):
    """When no fallback exists (the fail-safe itself fails), the original
    error surfaces through both the blocking send and the future path."""
    def boom(x):
        raise ValueError("kernel exploded")

    agent.registry.register(KernelRecord(alias="BOOM", fn=boom,
                                         platform="jnp", is_failsafe=True))
    cr = agent.claim("BOOM")
    with pytest.raises(ValueError, match="kernel exploded"):
        agent.send((jnp.ones(2),), cr)    # sync path
    fut = agent.isend((jnp.ones(2),), agent.claim("BOOM"))
    with pytest.raises(ValueError, match="kernel exploded"):
        fut.result(timeout=30)            # future path
    assert isinstance(fut.exception(), ValueError)


def test_execution_failure_engages_claim_callback_last(agent, rng):
    """Claim-level fail-safe callback engages only after every registered
    record (including the registry fail-safe) failed."""
    faulty = FaultyAgent(platform="xla", mode="raise")
    agent.attach_agent(faulty)

    def bad_ref(x):
        raise RuntimeError("oracle also down")

    reg = KernelRegistry()
    reg.register(KernelRecord(alias="K", fn=bad_ref, platform="xla",
                              priority=10))
    reg.register(KernelRecord(alias="K", fn=bad_ref, platform="jnp",
                              is_failsafe=True))
    agent2 = RuntimeAgent(registry=reg, manifest=default_manifest(),
                          agents=[faulty, VirtualizationAgent()])
    called = {}

    def cb(*args):
        called["yes"] = True
        return jnp.zeros(2)

    cr = agent2.claim("K", failsafe=cb)
    agent2.send((jnp.ones(2),), cr)
    np.testing.assert_allclose(agent2.recv(cr), 0.0)
    assert called.get("yes")
    agent2.finalize()


def test_selection_prefers_optimized(agent, rng):
    a = jax.random.normal(rng, (8, 8))
    rec = agent.registry.select("MMM", a, a,
                                allowed_platforms=["jnp", "xla", "pallas"],
                                platform_preference=["pallas", "xla", "jnp"])
    assert rec.platform == "pallas"   # small arrays: pallas feasible off-TPU


def test_selection_respects_supports_predicate(agent):
    """Oversized arrays off-TPU are infeasible for the pallas substrate."""
    big = jax.ShapeDtypeStruct((4096, 4096), jnp.float32)
    rec = agent.registry.select("MMM", big, big,
                                allowed_platforms=["jnp", "xla", "pallas"],
                                platform_preference=["pallas", "xla", "jnp"])
    assert rec.platform == "xla"


def test_sw_fid_lookup(agent, rng):
    """Resources resolve by sw_fid as well as alias (Table I/II)."""
    a = jax.random.normal(rng, (8, 8))
    rec = agent.registry.select("fid:mmm", a, a)
    assert rec.alias == "MMM"


def test_attribute_matching():
    attrs = KernelAttributes(vid="google", pid="tpu-v5e")
    assert attrs.matches(KernelAttributes(vid="google", pid="*"))
    assert not attrs.matches(KernelAttributes(vid="nvidia"))


def test_round_robin_among_ties():
    reg = KernelRegistry()
    seen = []
    for i in range(2):
        reg.register(KernelRecord(alias="X", fn=lambda i=i: i, platform="jnp",
                                  priority=5))
    picks = {reg.select("X").fn() for _ in range(4)}
    assert picks == {0, 1}      # round-robin cycles both replicas


def test_plug_and_play_register_deregister(agent, rng):
    class NewAgent(VirtualizationAgent):
        platform = "npu"

    agent.attach_agent(NewAgent())
    agent.registry.register(KernelRecord(
        alias="MMM", fn=lambda a, b: jnp.zeros((a.shape[0], b.shape[1])),
        platform="npu", priority=99))
    a = jnp.ones((4, 4))
    cr = agent.claim("MMM", overrides={
        "allowed_platforms": ["npu", "jnp"],
        "platform_preference": ["npu", "jnp"]})
    agent.send((a, a), cr)
    np.testing.assert_allclose(agent.recv(cr), 0.0)
    # disconnecting the platform must not affect host code (fail-safe path)
    agent.detach_agent("npu")
    agent.registry.deregister("MMM", "npu")
    cr2 = agent.claim("MMM")
    agent.send((a, a), cr2)
    np.testing.assert_allclose(agent.recv(cr2), a @ a)


def test_internal_buffers_stateful(agent):
    """MPIX_CreateBuffer turns a CR stateful; state persists across sends."""
    reg = agent.registry

    def accum(x, state):
        new = state["acc"] + x
        return new, {"acc": new}

    reg.register(KernelRecord(alias="ACCUM", fn=accum, platform="jnp",
                              is_failsafe=True))
    cr = agent.claim("ACCUM")
    agent.create_buffer(cr, (2,), jnp.float32, name="acc")
    agent.send((jnp.ones(2),), cr)
    agent.recv(cr)
    agent.send((jnp.ones(2),), cr)
    out = agent.recv(cr)
    np.testing.assert_allclose(out, 2.0)


def test_free_and_finalize(agent):
    cr = agent.claim("MMM")
    h = agent.create_buffer(cr, (2, 2), jnp.float32)
    agent.free(cr)
    assert cr.freed
    with pytest.raises(RuntimeError):
        agent.send((jnp.eye(2), jnp.eye(2)), cr)
    agent.finalize()
    with pytest.raises(RuntimeError):
        agent.claim("MMM")


def test_manifest_roundtrip(tmp_path):
    m = default_manifest()
    p = tmp_path / "manifest.json"
    m.to_json(p)
    m2 = Manifest.from_json(p)
    assert m2.func("MMM").sw_fid == "fid:mmm"
    assert m2.total_slots() == 512
    assert m2.platform_preference()[0] == "sharded"


def test_compute_object_pytree(rng):
    co = ComputeObject(inputs={"a": jnp.ones(3), "b": jnp.zeros(2)},
                       meta={"k": 1}, tag=5)
    leaves, tdef = jax.tree.flatten(co)
    co2 = jax.tree.unflatten(tdef, leaves)
    assert co2.tag == 5 and co2.meta == {"k": 1}
    assert not co.stateful
    co3 = co.with_buffer("s", BufferHandle.allocate((2,), jnp.float32))
    assert co3.stateful


def test_single_input_optimization():
    co = as_compute_object(jnp.ones(3))
    assert list(co.inputs) == ["arg000"]
    co = as_compute_object((jnp.ones(3), jnp.zeros(2)))
    assert sorted(co.inputs) == ["arg000", "arg001"]


def test_t1_overhead_instrumentation(agent, rng):
    a = jax.random.normal(rng, (16, 16))
    cr = agent.claim("MMM")
    agent.reset_t1()
    for _ in range(5):
        agent.send((a, a), cr)
        agent.recv(cr)
    assert agent.t1_seconds_per_call < 0.01   # dispatch path is cheap
    assert agent._t1_calls == 5
