"""Slot-based continuous batching (DESIGN.md §6): mid-flight admission,
independent retirement, slot reuse, EOS stop, legacy parity, no-echo flush."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import (RequestQueue, ServeEngine, SlotEngine,
                                StepScheduler)


@pytest.fixture(scope="module")
def danube(rng):
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    return cfg, model, model.init(rng)


@pytest.fixture(scope="module")
def mamba(rng):
    cfg = get_config("mamba2-370m").reduced()
    model = build_model(cfg)
    return cfg, model, model.init(rng)


def test_mixed_prompts_and_max_new_match_legacy(danube):
    """Concurrent submitters with mixed prompt lengths and max_new: greedy
    slot-engine outputs equal the legacy lockstep generate, per request."""
    cfg, model, params = danube
    engine = ServeEngine(model, max_len=48)
    sched = StepScheduler(SlotEngine(model, params, slots=2, max_len=48))
    cases = [([3, 1, 4, 1, 5], 3), ([2, 7, 1, 8, 2, 8, 1, 8], 6),
             ([9, 9, 8, 7, 6], 1), ([11, 12, 13], 4)]
    futs = [sched.submit(p, max_new=n) for p, n in cases]
    sched.drain()
    for (p, n), f in zip(cases, futs):
        ref = engine._generate_lockstep(params, jnp.asarray([p], jnp.int32), n)
        assert f.result(timeout=60) == list(map(int, np.asarray(ref)[0]))
    assert sched.completed == len(cases) and sched.active() == 0


def test_mid_flight_admission_before_any_retirement(danube):
    """Acceptance: a request submitted to a busy engine with one free slot
    begins decoding (streams its first token) before any in-flight request
    finishes — no batch-boundary wait."""
    cfg, model, params = danube
    sched = StepScheduler(SlotEngine(model, params, slots=2, max_len=48))
    events, lock = [], threading.Lock()
    a_mid_decode = threading.Event()

    def hook(name, notify_at=None):
        def on_token(tok, idx):
            with lock:
                events.append((name, idx))
            if notify_at is not None and idx >= notify_at:
                a_mid_decode.set()
        return on_token

    with sched:
        fa = sched.submit([1, 2, 3, 4], max_new=24,
                          on_token=hook("a", notify_at=2))
        fa.add_done_callback(lambda f: events.append(("a_done", -1)))
        assert a_mid_decode.wait(timeout=120)      # a is decoding, 1 slot free
        fb = sched.submit([4, 3, 2, 1, 5, 6], max_new=2,
                          on_token=hook("b"))
        ra = fa.result(timeout=120)
        rb = fb.result(timeout=120)
    assert len(ra) == 24 and len(rb) == 2
    with lock:
        b_first = events.index(("b", 0))
        a_done = events.index(("a_done", -1))
    assert b_first < a_done, events                # b decoded while a ran


def test_slot_reuse_after_retirement(mamba):
    """A single-slot engine serves a stream of requests sequentially —
    retirement frees the slot for the next admission — and each output
    equals the one-at-a-time legacy reference."""
    cfg, model, params = mamba
    engine = ServeEngine(model, max_len=24)
    sched = StepScheduler(SlotEngine(model, params, slots=1, max_len=24))
    futs = [sched.submit([1 + i, 2, 3], max_new=2 + i) for i in range(3)]
    sched.drain()
    outs = [f.result(timeout=60) for f in futs]
    assert [len(o) for o in outs] == [2, 3, 4]
    assert sched.completed == 3 and sched.active() == 0
    for i, o in enumerate(outs):
        ref = engine._generate_lockstep(
            params, jnp.asarray([[1 + i, 2, 3]], jnp.int32), 2 + i)
        assert o == list(map(int, np.asarray(ref)[0]))


def test_eos_stops_slot_and_queue_paths(danube):
    """Per-request EOS: no tokens after the sampled EOS appear in
    ``future.result()``, on both the slot engine and the compat queue."""
    cfg, model, params = danube
    engine = ServeEngine(model, max_len=48)
    prompt = [5, 6, 7, 8]
    ref = list(map(int, np.asarray(engine.generate(
        params, jnp.asarray([prompt], jnp.int32), 8))[0]))
    eos = ref[3]                                   # greedy will sample it
    cut = ref[: ref.index(eos) + 1]

    sched = StepScheduler(SlotEngine(model, params, slots=1, max_len=48))
    fut = sched.submit(prompt, max_new=8, eos_id=eos)
    sched.drain()
    out = fut.result(timeout=60)
    assert out == cut
    assert eos not in out[:-1]

    q = RequestQueue(engine, params, batch_size=2, prompt_len=len(prompt))
    f2 = q.submit(prompt, max_new=8, eos_id=eos)
    q.flush()
    assert f2.result(timeout=60) == cut


def test_request_queue_flush_has_no_echo_lanes(mamba):
    """Compat-path fix: a partial flush serves only live rows through one
    fixed-width slot pool (the old path echoed batch[0] into every empty
    lane and ran everyone to the batch max); each request retires at its own
    max_new, and the outputs match the lockstep reference."""
    cfg, model, params = mamba
    engine = ServeEngine(model, max_len=32)
    seen = []
    engine.generate = lambda *a, **kw: seen.append(a)   # must never be hit
    q = RequestQueue(engine, params, batch_size=8, prompt_len=8)
    f1 = q.submit([1, 2, 3], max_new=2)
    f2 = q.submit([4, 5, 6, 7], max_new=5)
    q.flush()
    assert seen == []                       # no whole-batch echo generate
    assert q._sched.engine.slots == 8       # one pool, one compiled width
    assert q._sched.completed == 2          # only the 2 live rows decoded
    out1, out2 = f1.result(timeout=60), f2.result(timeout=60)
    assert len(out1) == 2 and len(out2) == 5
    for prompt, out in ([1, 2, 3], out1), ([4, 5, 6, 7], out2):
        padded = (prompt + [0] * 8)[:8]
        ref = engine._generate_lockstep(
            params, jnp.asarray([padded], jnp.int32), len(out))
        assert out == list(map(int, np.asarray(ref)[0]))


def test_streaming_hooks_see_every_token_in_order(mamba):
    cfg, model, params = mamba
    sched = StepScheduler(SlotEngine(model, params, slots=2, max_len=24))
    got = {}
    futs = [sched.submit([1 + i, 2, 3], max_new=4,
                         on_token=lambda t, j, i=i:
                         got.setdefault(i, []).append((j, t)))
            for i in range(2)]
    sched.drain()
    for i, f in enumerate(futs):
        toks = f.result(timeout=60)
        assert got[i] == list(enumerate(toks))


def test_scorecard_accumulates(mamba):
    """The serving path emits the kernel path's T1/T3 scorecard."""
    cfg, model, params = mamba
    sched = StepScheduler(SlotEngine(model, params, slots=2, max_len=24))
    futs = [sched.submit([1, 2, 3, 4], max_new=3) for _ in range(2)]
    sched.drain()
    [f.result(timeout=60) for f in futs]
    rep = sched.report()
    # 2 iterations: admit (token 1) + decode (2), then decode (3) + retire
    assert rep.tokens == 6 and rep.steps >= 2
    assert rep.t3_s > 0 and rep.t1_s >= 0
    assert 0.0 <= rep.overhead < 1.0
    assert rep.t4_s == pytest.approx(rep.t1_s + rep.t3_s)


def test_engine_survives_failed_step(mamba):
    """A runtime failure inside a jitted call consumes the donated cache
    buffers; the scheduler fails the affected futures, the engine rebuilds
    the pool (ensure_caches), and later submissions are served normally."""
    cfg, model, params = mamba
    sched = StepScheduler(SlotEngine(model, params, slots=2, max_len=24))
    real_decode = sched.engine.decode_step

    def exploding_decode(*args, **kwargs):
        # simulate a post-dispatch device failure: donation consumed
        for leaf in jax.tree.leaves(sched.engine.caches):
            leaf.delete()
        raise RuntimeError("injected device failure")

    sched.engine.decode_step = exploding_decode
    fut = sched.submit([1, 2, 3], max_new=4)
    with pytest.raises(RuntimeError, match="injected"):
        sched.step()                               # admit + exploding decode
    with pytest.raises(RuntimeError, match="injected"):
        fut.result(timeout=60)

    sched.engine.decode_step = real_decode         # "device" recovers
    ok = sched.submit([1, 2, 3], max_new=4)
    sched.drain()
    ref = ServeEngine(model, max_len=24)._generate_lockstep(
        params, jnp.asarray([[1, 2, 3]], jnp.int32), 4)
    assert ok.result(timeout=60) == list(map(int, np.asarray(ref)[0]))


def test_submit_validation(mamba):
    cfg, model, params = mamba
    sched = StepScheduler(SlotEngine(model, params, slots=1, max_len=16))
    with pytest.raises(ValueError):
        sched.submit([], max_new=2)
    with pytest.raises(ValueError):
        sched.submit([1, 2], max_new=0)
    with pytest.raises(ValueError):
        sched.submit([1] * 12, max_new=8)          # 12 + 8 > 16


# ---------------------------------------------------------------------------
# Paged engine (DESIGN.md §14): parity vs the dense slot reference,
# admission/QoS policy, and buffer-release regression
# ---------------------------------------------------------------------------
from repro.serve.engine import (AdmissionError, AdmissionPolicy, PagedEngine,
                                QoSClass)


def _drive_pair(model, params, prompts, max_new, *, max_len=48, slots=2,
                **paged_kw):
    """Run the same workload through the dense and paged engines; returns
    (dense outputs, paged outputs, paged engine)."""
    outs = []
    paged = None
    for make in (lambda: SlotEngine(model, params, slots=slots,
                                    max_len=max_len),
                 lambda: PagedEngine(model, params, slots=slots,
                                     max_len=max_len, **paged_kw)):
        eng = make()
        sched = StepScheduler(eng, seed=3)
        futs = [sched.submit(list(p), max_new=n)
                for p, n in zip(prompts, max_new)]
        sched.drain()
        outs.append([f.result(timeout=60) for f in futs])
        if isinstance(eng, PagedEngine):
            paged = eng
    return outs[0], outs[1], paged


def test_paged_whole_prompt_bit_parity(danube):
    """chunk_tokens=0 reuses the dense engine's exact prefill program, so
    greedy outputs are bit-identical — including decode past the SWA ring
    wrap (prompt 30 + 14 > window 32)."""
    cfg, model, params = danube
    prompts = [[3, 1, 4, 1, 5], list(range(1, 31)), [9, 9, 8], [2] * 12]
    dense, paged, eng = _drive_pair(model, params, prompts, [3, 14, 6, 4],
                                    block_size=8, chunk_tokens=0)
    assert dense == paged
    eng.pool.check()
    assert eng.pool.live_blocks() == 0 and eng.pool.reserved == 0


def test_paged_whole_prompt_bit_parity_lane_state(mamba):
    """Mamba lanes carry O(1) state (no seq axis): the paged engine still
    serves them (admission accounting only) with bit-identical outputs."""
    cfg, model, params = mamba
    prompts = [[1, 2, 3], [4, 5, 6, 7, 8, 9, 10], [11, 12, 13]]
    dense, paged, eng = _drive_pair(model, params, prompts, [4, 6, 2],
                                    max_len=24, block_size=8)
    assert dense == paged
    eng.pool.check()


def test_paged_chunked_prefill_matches_dense(danube):
    """Greedy outputs across chunked-prefill boundaries (prompt 30, chunk
    16, block 8) equal the dense engine's, and admission really was
    chunked (multiple prefill iterations per long prompt)."""
    cfg, model, params = danube
    assert model.supports_chunked_prefill()
    prompts = [list(range(1, 31)), [7, 7, 7], list(range(40, 58))]
    dense, paged, eng = _drive_pair(model, params, prompts, [6, 4, 6],
                                    block_size=8, chunk_tokens=16)
    assert dense == paged
    eng.pool.check()


def test_paged_shared_prefix_reuses_blocks_and_forks_on_write(danube):
    """A second request arriving once the first is decoding reuses its
    registered 24-token prefix chain (prefix hits); the SWA ring wrap then
    writes into a shared block while both lanes are live, forcing a COW
    fork.  Outputs still match the dense engine and every block returns at
    drain."""
    cfg, model, params = danube
    shared = list(range(100, 124))                 # exactly 3 blocks of 8
    prompts = [shared + [1, 2, 3, 4, 5], shared + [9, 8, 7, 6, 5, 4]]

    ref = StepScheduler(SlotEngine(model, params, slots=2, max_len=48),
                        seed=3)
    refs = [ref.submit(list(p), max_new=14) for p in prompts]
    ref.drain()
    dense = [f.result(timeout=60) for f in refs]

    eng = PagedEngine(model, params, slots=2, max_len=48, block_size=8,
                      chunk_tokens=16)
    sched = StepScheduler(eng, seed=3)
    f1 = sched.submit(prompts[0], max_new=14)
    while sched.active() == 0 or any(
            l is not None and l.prefilling for l in sched._lanes):
        sched.step()                               # finish req 1's prefill
    f2 = sched.submit(prompts[1], max_new=14)      # arrives mid-decode
    sched.drain()
    assert [f1.result(timeout=60), f2.result(timeout=60)] == dense
    st = eng.stats()
    assert st["prefix_hits"] >= 3                  # chain reused at admit
    assert st["forks"] >= 1                        # COW on the wrap write
    eng.pool.check()
    assert eng.pool.live_blocks() == 0 and eng.pool.reserved == 0


def test_paged_admission_depth_cap_rejects(danube):
    cfg, model, params = danube
    eng = PagedEngine(model, params, slots=1, max_len=48, block_size=8)
    pol = AdmissionPolicy(classes={"bulk": QoSClass(max_depth=1)})
    sched = StepScheduler(eng, policy=pol)
    keep = sched.submit([1, 2, 3], max_new=2, qos="bulk")
    with pytest.raises(AdmissionError, match="queue is full"):
        sched.submit([4, 5, 6], max_new=2, qos="bulk")
    # other classes are unaffected by the bulk cap
    other = sched.submit([4, 5, 6], max_new=2)
    sched.drain()
    assert len(keep.result(timeout=60)) == 2
    assert len(other.result(timeout=60)) == 2
    assert sched.rejected == 1


def test_paged_admission_max_delay_expires_queued(danube):
    """A queued request older than its class max_delay fails with
    AdmissionError at the next step instead of waiting forever."""
    cfg, model, params = danube
    eng = PagedEngine(model, params, slots=1, max_len=48, block_size=8)
    pol = AdmissionPolicy(classes={"rt": QoSClass(max_delay=0.0)})
    sched = StepScheduler(eng, policy=pol)
    doomed = sched.submit([1, 2, 3], max_new=4, qos="rt")
    time.sleep(0.01)
    sched.drain()
    with pytest.raises(AdmissionError, match="waited"):
        doomed.result(timeout=60)
    assert sched.expired == 1
    ok = sched.submit([1, 2, 3], max_new=2)        # engine still serves
    sched.drain()
    assert len(ok.result(timeout=60)) == 2


def test_paged_watermark_defers_admission_until_blocks_free(danube):
    """With a free-block watermark, a request that would dip the arena
    below the floor waits in queue until a lane retires — then serves
    normally (admission is deferred, not dropped)."""
    cfg, model, params = danube
    # capacity 13: each (prompt 8 + max_new 8) lane needs 2 blocks
    eng = PagedEngine(model, params, slots=2, max_len=48, block_size=8,
                      num_blocks=14)
    sched = StepScheduler(eng, policy=AdmissionPolicy(watermark=0.77))
    futs = [sched.submit([i] * 8, max_new=8) for i in range(3)]
    # floor = int(0.77 * 13) = 10 free blocks: the empty arena (headroom
    # 13 - need 2 = 11) admits one lane, but with it holding a block and a
    # reservation (headroom 9) the next request must wait
    assert sched.step()
    assert sched.active() == 1 and sched.pending() == 2
    sched.drain()
    for f in futs:
        assert len(f.result(timeout=60)) == 8
    eng.pool.check()


def test_failed_batch_releases_cache_buffers(mamba):
    """Regression (RequestQueue.flush whole-batch failure): when a failed
    jitted call consumes only part of the donated cache tree, ensure_caches
    must delete the surviving leaves before rebuilding — otherwise they
    stay resident alongside the new pool until GC."""
    cfg, model, params = mamba
    sched = StepScheduler(SlotEngine(model, params, slots=2, max_len=24))
    old_leaves = jax.tree.leaves(sched.engine.caches)
    real_decode = sched.engine.decode_step

    def half_dead_decode(*args, **kwargs):
        # consume a strict subset of the donation, then fail
        old_leaves[0].delete()
        raise RuntimeError("injected partial donation failure")

    sched.engine.decode_step = half_dead_decode
    fut = sched.submit([1, 2, 3], max_new=4)
    with pytest.raises(RuntimeError, match="injected"):
        sched.step()
    with pytest.raises(RuntimeError):
        fut.result(timeout=60)
    assert all(leaf.is_deleted() for leaf in old_leaves), \
        "surviving donated buffers were stranded across the rebuild"
    sched.engine.decode_step = real_decode
    ok = sched.submit([1, 2, 3], max_new=3)
    sched.drain()
    assert len(ok.result(timeout=60)) == 3


def test_paged_failed_decode_releases_blocks(danube):
    """A decode failure on the paged path frees every failed lane's blocks
    (no arena leak) and later submissions serve from a rebuilt arena."""
    cfg, model, params = danube
    eng = PagedEngine(model, params, slots=2, max_len=48, block_size=8)
    sched = StepScheduler(eng)
    real_decode = eng.decode_step

    def exploding_decode(*args, **kwargs):
        for leaf in jax.tree.leaves(eng.paged):
            leaf.delete()
        raise RuntimeError("injected paged decode failure")

    eng.decode_step = exploding_decode
    fut = sched.submit([1, 2, 3, 4, 5, 6, 7, 8, 9], max_new=6)
    with pytest.raises(RuntimeError, match="injected"):
        sched.step()
    with pytest.raises(RuntimeError):
        fut.result(timeout=60)
    eng.pool.check()
    assert eng.pool.live_blocks() == 0 and eng.pool.reserved == 0
    assert eng.pool.available() == eng.pool.capacity

    eng.decode_step = real_decode
    ok = sched.submit([1, 2, 3], max_new=4)
    sched.drain()
    ref = StepScheduler(SlotEngine(model, params, slots=2, max_len=48))
    rf = ref.submit([1, 2, 3], max_new=4)
    ref.drain()
    assert ok.result(timeout=60) == rf.result(timeout=60)
