"""Property-based allocator tests for the paged KV cache (DESIGN.md §14).

The :class:`~repro.serve.kvcache.BlockPool` is held to *invariants*, not
examples: a randomized driver replays the engine's admit / decode / COW-fork
/ retire protocol against the pool and calls ``pool.check()`` after **every**
operation, so a violation surfaces at the op that caused it, not at drain.
Prompts are drawn from a small set of shared stems so prefix matches, COW
forks and LRU evictions all occur organically.

The suite runs 500+ interleavings with or without hypothesis: the driver is
plain code, the bulk test iterates seeds directly, and hypothesis (when
installed) adds shrinking on top.  A device-level test pins the COW
guarantee itself: forking then writing the fork never mutates the shared
source block's bytes.
"""
import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # hypothesis is an optional extra
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*a, **k):
        return lambda fn: fn

    class _NoStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NoStrategies()

from repro.serve.kvcache import (BlockPool, NoFreeBlocks, copy_block,
                                 gather_views, init_paged, leaf_layout,
                                 prefix_block_keys)

BS = 4            # block size for the model-based driver
CAP = 16          # pool capacity (num_blocks - 1)


def ceil_div(a, b):
    return -(-a // b)


class _Lane:
    """Shadow of one serving lane: its block chain + unspent reservation."""

    def __init__(self, blocks, resv, prompt, pos, limit):
        self.blocks = blocks          # bids, in table order
        self.resv = resv              # worst-case blocks not yet drawn down
        self.prompt = prompt
        self.pos = pos                # tokens written so far
        self.limit = limit            # s0 + max_new: the reserved budget


def _alloc(pool, lane):
    """The engine's allocation rule: spend the lane's reservation first."""
    if lane.resv > 0:
        lane.resv -= 1
        return pool.alloc(reserved=True)
    return pool.alloc()


def _admit(pool, rng, lanes, stems):
    """Reserve worst case, reuse a matched prefix chain, alloc the rest."""
    stem = rng.choice(stems)
    s0 = rng.randrange(1, 4 * BS)
    prompt = (stem + [rng.randrange(256) for _ in range(64)])[:s0]
    max_new = rng.randrange(1, 2 * BS)
    need = ceil_div(s0 + max_new, BS)
    if not pool.can_reserve(need):
        return                                     # admission gated: no lane
    pool.reserve(need)
    keys = prefix_block_keys(prompt, BS, limit=(s0 - 1) // BS)
    matched = pool.match_prefix(keys)
    blocks = list(matched)
    lane = _Lane(blocks, need, prompt, len(matched) * BS, s0 + max_new)
    lanes.append(lane)
    pool.check()
    while lane.pos < s0:                           # prefill the remainder
        blocks.append(_alloc(pool, lane))
        pool.check()
        lane.pos = min(s0, lane.pos + BS)


def _decode(pool, rng, lanes):
    """Write one token: tail alloc at a block boundary; a wrap-style write
    into an existing block forks it when shared, unregisters it when not."""
    if not lanes:
        return
    lane = rng.choice(lanes)
    if lane.pos >= lane.limit:                     # lane exhausted its budget
        return
    if lane.pos % BS == 0 and rng.random() < 0.7:
        lane.blocks.append(_alloc(pool, lane))
    elif lane.blocks:
        i = rng.randrange(len(lane.blocks))        # ring wrap lands anywhere
        bid = lane.blocks[i]
        if pool.refcount(bid) > 1:
            if lane.resv > 0:
                lane.resv -= 1
                lane.blocks[i] = pool.fork(bid, reserved=True)
            elif pool.available() - pool.reserved >= 1:
                lane.blocks[i] = pool.fork(bid)
        elif pool.is_registered(bid):
            pool.unregister(bid)
    lane.pos += 1


def _retire(pool, rng, lanes):
    if not lanes:
        return
    lane = lanes.pop(rng.randrange(len(lanes)))
    if rng.random() < 0.6:                         # publish prompt blocks
        for i, key in enumerate(prefix_block_keys(lane.prompt, BS)):
            if i < len(lane.blocks) and pool.refcount(lane.blocks[i]) >= 1:
                pool.register_prefix(lane.blocks[i], key)
    for bid in lane.blocks:
        pool.deref(bid)
    pool.unreserve(lane.resv)


def drive(seed, steps=60):
    """One random interleaving; checks invariants after every operation."""
    rng = random.Random(seed)
    pool = BlockPool(CAP + 1, BS)
    stems = [[rng.randrange(256) for _ in range(3 * BS)] for _ in range(3)]
    lanes = []
    for _ in range(steps):
        op = rng.random()
        if op < 0.25:
            _admit(pool, rng, lanes, stems)
        elif op < 0.8:
            _decode(pool, rng, lanes)
        else:
            _retire(pool, rng, lanes)
        pool.check()
    while lanes:                                   # drain
        _retire(pool, rng, lanes)
        pool.check()
    assert pool.live_blocks() == 0                 # every refcount back at 0
    assert pool.reserved == 0
    assert pool.available() == pool.capacity       # zero leaked blocks
    return pool


def test_random_interleavings_never_leak():
    """500+ random admit/decode/fork/retire interleavings: no leak, no
    double free, refcounts return to zero at drain.  Runs everywhere —
    hypothesis only adds shrinking on top of this sweep."""
    hits = forks = evictions = 0
    for seed in range(520):
        pool = drive(seed)
        hits += pool.prefix_hits
        forks += pool.forks
        evictions += pool.evictions
    # the sweep must actually exercise the interesting paths
    assert hits > 100 and forks > 100 and evictions > 20


@given(seed=st.integers(0, 2**32 - 1), steps=st.integers(1, 120))
@settings(max_examples=50, deadline=None)
def test_random_interleavings_hypothesis(seed, steps):
    drive(seed, steps)


def test_double_free_raises():
    pool = BlockPool(8, BS)
    bid = pool.alloc()
    pool.deref(bid)
    with pytest.raises(ValueError, match="double free"):
        pool.deref(bid)
    pool.check()


def test_exhaustion_raises_not_corrupts():
    pool = BlockPool(4, BS)                        # capacity 3
    bids = [pool.alloc() for _ in range(3)]
    with pytest.raises(NoFreeBlocks):
        pool.alloc()
    pool.check()
    for b in bids:
        pool.deref(b)
    assert pool.available() == pool.capacity


def test_reservations_gate_unreserved_allocs():
    pool = BlockPool(6, BS)                        # capacity 5
    pool.reserve(4)
    pool.alloc()                                   # 1 beside the reservation
    with pytest.raises(NoFreeBlocks):
        pool.alloc()                               # would invade it
    assert pool.alloc(reserved=True) is not None   # the reservation itself
    pool.check()
    with pytest.raises(ValueError):
        pool.unreserve(4)                          # only 3 still reserved


def test_fork_requires_sharing_and_moves_one_ref():
    pool = BlockPool(8, BS)
    bid = pool.alloc()
    with pytest.raises(ValueError, match="unshared"):
        pool.fork(bid)
    pool.ref(bid)                                  # second lane joins
    new = pool.fork(bid)                           # second lane goes private
    assert new != bid
    assert pool.refcount(bid) == 1 and pool.refcount(new) == 1
    pool.check()


def test_match_revives_from_reusable_and_eviction_unregisters():
    pool = BlockPool(4, BS)                        # capacity 3
    keys = prefix_block_keys([1, 2, 3, 4, 5, 6, 7, 8], BS)
    chain = [pool.alloc(), pool.alloc()]
    for bid, key in zip(chain, keys):
        assert pool.register_prefix(bid, key)
    for bid in chain:
        pool.deref(bid)                            # park on the reusable LRU
    assert pool.live_blocks() == 0
    assert pool.match_prefix(keys) == chain        # revived, ref'd again
    for bid in chain:
        pool.deref(bid)
    # allocation pressure evicts LRU reusable blocks and their registration
    got = [pool.alloc() for _ in range(3)]
    assert pool.evictions >= 2 and set(chain) <= set(got)
    assert pool.match_prefix(keys) == []
    pool.check()


def test_prefix_block_keys_chain():
    toks = list(range(10))
    keys = prefix_block_keys(toks, 4)
    assert keys == [(0, 1, 2, 3), (0, 1, 2, 3, 4, 5, 6, 7)]
    assert prefix_block_keys(toks, 4, limit=1) == [(0, 1, 2, 3)]
    assert prefix_block_keys(toks[:3], 4) == []


def test_cow_fork_never_mutates_shared_block(rng):
    """Device-level COW: fork a shared block, write the fork, and assert the
    source block's bytes are untouched (and the sharer still reads them)."""
    from repro.configs import get_config
    cfg = get_config("h2o-danube-1.8b").reduced()
    bs, nblocks, max_len = 4, 9, 16
    layout = leaf_layout(cfg, max_len)
    paged = init_paged(cfg, slots=2, max_len=max_len, num_blocks=nblocks,
                       block_size=bs)
    import jax
    import jax.numpy as jnp
    # fill block 1 with recognizable content, table both slots onto it
    paged = jax.tree.map(
        lambda ls, a: a.at[:, 1].set(1.0) if ls.kind == "seq" else a,
        layout, paged, is_leaf=lambda x: hasattr(x, "kind"))
    src_before = [np.asarray(a[:, 1]) for ls, a in
                  zip(jax.tree.leaves(layout, is_leaf=lambda x:
                      hasattr(x, "kind")), jax.tree.leaves(paged))
                  if ls.kind == "seq"]
    # COW: slot 1 forks block 1 -> block 2, then overwrites its copy
    paged = copy_block(layout, paged, jnp.int32(1), jnp.int32(2))
    paged = jax.tree.map(
        lambda ls, a: a.at[:, 2].mul(-3.0) if ls.kind == "seq" else a,
        layout, paged, is_leaf=lambda x: hasattr(x, "kind"))
    seq_arenas = [(ls, a) for ls, a in
                  zip(jax.tree.leaves(layout, is_leaf=lambda x:
                      hasattr(x, "kind")), jax.tree.leaves(paged))
                  if ls.kind == "seq"]
    for (ls, a), before in zip(seq_arenas, src_before):
        np.testing.assert_array_equal(np.asarray(a[:, 1]), before)
        assert np.all(np.asarray(a[:, 2]) == -3.0)   # fork took the write
    # a reader tabled on the original still sees the original content
    tables = jnp.asarray([[1, 0, 0, 0], [2, 0, 0, 0]], jnp.int32)
    views = gather_views(layout, paged, tables, bs)
    for ls, v in zip(jax.tree.leaves(layout, is_leaf=lambda x:
                     hasattr(x, "kind")), jax.tree.leaves(views)):
        if ls.kind != "seq":
            continue
        first = np.moveaxis(np.asarray(v), ls.seq_axis, -1)[..., :bs]
        assert np.all(first[:, 0] == 1.0) and np.all(first[:, 1] == -3.0)
