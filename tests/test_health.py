"""Agent liveness (DESIGN.md §11): heartbeat detection, DEAD-agent queue
replay, health-config knobs, and serving-scheduler lane failure.

Every test drives ``HealthMonitor.check(now=...)`` synchronously with an
injected clock, so state transitions are deterministic and nothing sleeps
for more than a few milliseconds at a time."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AgentDeadError, AgentState, HealthConfig,
                        HealthMonitor, KernelRegistry, RuntimeAgent,
                        default_manifest)
from repro.kernels import register_all
from repro.serve.engine import Request, StepScheduler, _Lane
from repro.testing.faults import FaultPlan, chaos


@pytest.fixture()
def session():
    registry = KernelRegistry()
    register_all(registry)
    s = RuntimeAgent(registry=registry, manifest=default_manifest())
    yield s
    s.finalize()


def _wait_until(cond, timeout=5.0, what="condition"):
    deadline = time.monotonic() + timeout
    while not cond():
        assert time.monotonic() < deadline, f"{what} not reached in time"
        time.sleep(0.005)


# -- config knobs -------------------------------------------------------------
def test_health_config_from_env(monkeypatch):
    monkeypatch.setenv("HALO_HEARTBEAT_TIMEOUT", "2.5")
    monkeypatch.setenv("HALO_HEALTH_POLL", "0.5")
    monkeypatch.setenv("HALO_STRAGGLER_MULTIPLE", "3")
    monkeypatch.setenv("HALO_STRAGGLER_MIN", "0.125")
    cfg = HealthConfig.from_env()
    assert cfg.heartbeat_timeout == 2.5
    assert cfg.poll_interval == 0.5 and cfg.effective_poll == 0.5
    assert cfg.straggler_multiple == 3.0
    assert cfg.straggler_min_s == 0.125
    # explicit keyword overrides beat the environment
    assert HealthConfig.from_env(heartbeat_timeout=9.0).heartbeat_timeout == 9.0
    # junk values fall back to defaults instead of crashing startup
    monkeypatch.setenv("HALO_HEARTBEAT_TIMEOUT", "banana")
    assert HealthConfig.from_env().heartbeat_timeout == 30.0


def test_effective_poll_defaults_to_quarter_timeout():
    assert HealthConfig(heartbeat_timeout=8.0).effective_poll == 2.0
    assert HealthConfig(heartbeat_timeout=8.0,
                        poll_interval=0.1).effective_poll == 0.1


def test_env_auto_enables_monitor(monkeypatch):
    monkeypatch.setenv("HALO_HEALTH_MONITOR", "1")
    registry = KernelRegistry()
    register_all(registry)
    s = RuntimeAgent(registry=registry, manifest=default_manifest())
    try:
        assert s.health is not None
    finally:
        s.finalize()


# -- heartbeat classification -------------------------------------------------
def test_idle_agents_stay_healthy(session):
    mon = session.enable_health_monitor(
        config=HealthConfig(heartbeat_timeout=0.2), start=False)
    # far-future sweep: idle targets never degrade, however stale their clock
    states = mon.check(now=time.monotonic() + 1e6)
    assert set(states.values()) == {AgentState.HEALTHY}


def test_completed_work_advances_heartbeat(session):
    jnp_agent = session.agents["jnp"]
    beats0, _, _ = jnp_agent.heartbeat()
    cr = session.claim("MMM", overrides={"allowed_platforms": ["jnp"],
                                         "platform_preference": ["jnp"]})
    session.send((jnp.eye(4), jnp.eye(4)), cr)
    session.recv(cr)
    beats1, busy, _ = jnp_agent.heartbeat()
    assert beats1 > beats0
    _wait_until(lambda: not jnp_agent.heartbeat()[1], what="agent idle")


def test_hung_worker_degrades_then_dies_and_replays(session):
    """The full tentpole arc, clock-driven: a wedged worker is DEGRADED at
    half the timeout, DEAD at the timeout, and its in-flight request is
    replayed onto the fail-safe agent with the correct result."""
    mon = session.enable_health_monitor(
        config=HealthConfig(heartbeat_timeout=0.2, degraded_fraction=0.5),
        start=False)
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 16))
    with chaos(session, FaultPlan(platform="xla", mode="die")) as faulty:
        cr = session.claim("MMM", overrides={
            "allowed_platforms": ["xla", "jnp"],
            "platform_preference": ["xla", "jnp"]})
        fut = session.isend((a, a), cr, mailbox=False)
        _wait_until(lambda: faulty.failures >= 1, what="worker wedged")
        _, busy, last = faulty.heartbeat()
        assert busy
        assert mon.check(now=last + 0.05)[faulty.name] == AgentState.HEALTHY
        assert mon.check(now=last + 0.11)[faulty.name] == AgentState.DEGRADED
        assert mon.check(now=last + 0.21)[faulty.name] == AgentState.DEAD
        # DEAD is sticky and the transition already healed the session:
        assert faulty.dead and not faulty.available()
        with pytest.raises(AgentDeadError):
            faulty.submit(lambda: None)
        out = fut.result(timeout=30)
        np.testing.assert_allclose(np.asarray(out), np.asarray(a) @
                                   np.asarray(a), rtol=1e-4, atol=1e-4)


def test_dead_agent_replays_whole_queue(session):
    """In-flight AND still-queued requests of a dead agent all complete on
    the fail-safe substrate."""
    mats = [jax.random.normal(jax.random.PRNGKey(i), (12, 12))
            for i in range(3)]
    with chaos(session, FaultPlan(platform="xla", mode="die")) as faulty:
        cr = session.claim("MMM", overrides={
            "allowed_platforms": ["xla", "jnp"],
            "platform_preference": ["xla", "jnp"]})
        futs = [session.isend((m, m), cr, mailbox=False) for m in mats]
        _wait_until(lambda: faulty.failures >= 1, what="worker wedged")
        replayed = session.handle_dead_agent(faulty, reason="test kill")
        assert replayed == 3
        for m, f in zip(mats, futs):
            np.testing.assert_allclose(np.asarray(f.result(timeout=30)),
                                       np.asarray(m) @ np.asarray(m),
                                       rtol=1e-4, atol=1e-4)
        assert faulty.dead
        # idempotent: a second declaration finds nothing left to recover
        assert session.handle_dead_agent(faulty) == 0


def test_reregistration_resets_dead_state(session):
    mon = session.enable_health_monitor(
        config=HealthConfig(heartbeat_timeout=0.2), start=False)
    agent = session.agents["jnp"]
    mon.mark_dead(agent)
    assert mon.state(agent) == AgentState.DEAD
    mon.register(agent)           # explicit recovery path
    assert mon.state(agent) == AgentState.HEALTHY


def test_watch_fires_once_and_unwatch_cancels():
    mon = HealthMonitor(HealthConfig(heartbeat_timeout=1.0))
    fired = []
    now = time.monotonic()
    tok1 = mon.watch(now + 0.05, lambda: fired.append(1))
    tok2 = mon.watch(now + 0.05, lambda: fired.append(2))
    mon.unwatch(tok2)
    mon.check(now=now)            # before the deadline: nothing fires
    assert fired == []
    mon.check(now=now + 0.1)
    mon.check(now=now + 0.2)      # one-shot: no refire
    assert fired == [1]
    assert tok1 != tok2


# -- serving lane failure -----------------------------------------------------
class _StubEngine:
    """Engine stand-in: the scheduler only reads slots/max_len until a step
    actually runs, which these tests never do (the point is the hang)."""
    slots = 2
    max_len = 64


def test_slot_scheduler_heartbeat_and_dead_failure():
    """A serving scheduler nobody is stepping (or whose stepper is wedged in
    a device call) goes DEAD, and every queued request and occupied lane
    fails with AgentDeadError instead of blocking its client forever."""
    sched = StepScheduler(_StubEngine())
    mon = HealthMonitor(HealthConfig(heartbeat_timeout=0.2))
    sched.attach_health(mon)
    queued = sched.submit([1, 2, 3], max_new=4)
    from repro.core import HaloFuture
    lane_fut = HaloFuture(uid=99, alias="generate")
    lane_req = Request(99, [1, 2], 8, future=lane_fut)
    with sched._cond:
        sched._lanes[0] = _Lane(lane_req, pos=2, last_tok=1, tokens=[1])
    beats, busy, last = sched.heartbeat()
    assert busy
    assert mon.check(now=last + 0.05)[sched.name] == AgentState.HEALTHY
    assert mon.check(now=last + 0.3)[sched.name] == AgentState.DEAD
    with pytest.raises(AgentDeadError):
        queued.result(timeout=5)
    with pytest.raises(AgentDeadError):
        lane_fut.result(timeout=5)
    assert sched.pending() == 0 and sched.active() == 0


def test_slot_scheduler_step_advances_beat():
    sched = StepScheduler(_StubEngine())
    beats0, busy, _ = sched.heartbeat()
    assert not busy
    assert sched.step() is False        # idle step: no work, still beats
    beats1, _, _ = sched.heartbeat()
    assert beats1 > beats0
