"""Async C2MPI surface: MPIX_ISend/IRecv/Wait/Test futures, per-tag FIFO
ordering under concurrency, cancellation, and error propagation (DESIGN.md §4).
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (HaloCancelledError, HaloFuture, KernelRecord,
                        KernelRegistry, RuntimeAgent, default_manifest)
from repro.kernels import register_all
from repro.kernels.spmm import dense_to_bell, random_block_sparse


@pytest.fixture()
def agent():
    registry = KernelRegistry()
    register_all(registry)
    a = RuntimeAgent(registry=registry, manifest=default_manifest())
    yield a
    a.finalize()


def _alias_args(rng):
    """Valid positional args for every registered kernel alias."""
    k = jax.random.split(rng, 8)
    n = 64
    a = jax.random.normal(k[0], (n, n))
    b = jax.random.normal(k[1], (n, n)) + 3.0
    x = jax.random.normal(k[2], (n,))
    sp = random_block_sparse(k[3], n, n, 32, 64, 0.5)
    vals, idx = dense_to_bell(sp, 32, 64)
    q = jax.random.normal(k[4], (1, 4, 32, 32))
    kv = jax.random.normal(k[5], (1, 2, 32, 32))
    B, S, H, P, G, N = 1, 32, 2, 8, 1, 16
    ks = jax.random.split(k[6], 6)
    ssd_x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    ssd_dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    ssd_a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    ssd_b = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    ssd_c = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    ssd_d = jax.random.normal(ks[5], (H,)) * 0.1
    km = jax.random.split(k[7], 4)
    from repro.train.step_kernels import param_size, resolve_arch
    step_kw = dict(arch="h2o-danube-1.8b", reduced=True)
    p = param_size(**step_kw)
    v = resolve_arch(**step_kw).vocab_size
    pvec = jax.random.normal(km[0], (p,)) * 0.02
    toks = jax.random.randint(km[1], (2, 16), 0, v)
    return {
        "MMM": (a, b),
        "EWMM": (a, b),
        "EWMD": (a, b),
        "EWADD": (a, b),
        "EWSUB": (a, b),
        "COPY": (a,),
        "CONCAT": (a, b),
        "MVM": (a, x),
        "VDP": (x, x),
        "JS": (a + n * jnp.eye(n), jnp.zeros(n), x),
        "1DCONV": (jax.random.normal(k[0], (2048,)),
                   jax.random.normal(k[1], (9,))),
        "SMMM": (vals, idx, b),
        "RMSNORM": (a, x),
        "FLASH_ATTN": (q, kv, kv),
        "GQA_DECODE": (q, kv, kv),
        "SSD": (ssd_x, ssd_dt, ssd_a, ssd_b, ssd_c, ssd_d),
        "SSD_DECODE": (jnp.zeros((B, H, P, N)), ssd_x[:, 0], ssd_dt[:, 0],
                       ssd_a, ssd_b[:, 0], ssd_c[:, 0], ssd_d),
        "MOE_FFN": (jax.random.normal(km[0], (2, 4, 16)),
                    jax.random.normal(km[1], (2, 16, 32)) * 0.1,
                    jax.random.normal(km[2], (2, 16, 32)) * 0.1,
                    jax.random.normal(km[3], (2, 32, 16)) * 0.1),
        "FFT": (a[:8],),
        "SORT": (x,),
        "HIST": (jax.nn.sigmoid(x),),
        "LM_GRAD": ((pvec, toks, jnp.roll(toks, -1, 1),
                     jnp.ones((2, 16), jnp.float32)), step_kw),
        "ADAMW_STEP": ((jax.random.normal(km[2], (p + 1,)) * 0.01, pvec,
                        jnp.zeros_like(pvec), jnp.zeros_like(pvec),
                        jnp.asarray(0, jnp.int32)),
                       dict(step_kw, n_micro=2)),
    }


def test_isend_wait_matches_blocking_for_all_registered_aliases(agent, rng):
    """Acceptance: async round trips are bit-for-bit comparable with the
    blocking path for every alias in the registry."""
    jobs = _alias_args(rng)
    assert sorted(jobs) == agent.registry.aliases()   # full coverage
    for alias, job in jobs.items():
        args, kwargs = (job if len(job) == 2 and isinstance(job[1], dict)
                        else (job, {}))
        cr_sync = agent.claim(alias)
        agent.send(args, cr_sync, **kwargs)
        ref = agent.recv(cr_sync)
        cr_async = agent.claim(alias)
        fut = agent.isend(args, cr_async, **kwargs)
        out = jax.block_until_ready(fut.result(timeout=60))
        for l_ref, l_out in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
            np.testing.assert_allclose(np.asarray(l_out), np.asarray(l_ref),
                                       rtol=2e-4, atol=2e-4, err_msg=alias)
        # the mailbox still serves the same result to a blocking recv
        out2 = agent.recv(cr_async)
        for l_out, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(out2)):
            np.testing.assert_array_equal(np.asarray(l_out), np.asarray(l2))


def test_fifo_per_tag_under_concurrent_isend(agent):
    """Many threads isend-ing interleaved tags on one CR: per-tag recv order
    must equal per-tag submission order (the paper's FIFO mailbox rule)."""
    eye = jnp.eye(4)
    cr = agent.claim("MMM")
    n_threads, n_each = 4, 16
    barrier = threading.Barrier(n_threads)

    # each thread owns one tag, so per-tag submission order is the thread's
    # own program order even though threads interleave globally
    def worker(tag):
        barrier.wait()
        for i in range(n_each):
            agent.isend((eye * (i + 1), eye), cr, tag=tag)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for tag in range(n_threads):
        got = [int(np.asarray(agent.recv(cr, tag=tag))[0, 0]) - 1
               for _ in range(n_each)]
        assert got == list(range(n_each)), tag


def test_irecv_posted_before_send_completes(agent):
    cr = agent.claim("MMM")
    waiter = agent.irecv(cr, tag=3)
    assert not waiter.done()
    agent.isend((2.0 * jnp.eye(4), jnp.eye(4)), cr, tag=3)
    np.testing.assert_allclose(np.asarray(waiter.result(timeout=30)),
                               2.0 * np.eye(4))
    # a second send on the tag goes to the mailbox, not the used-up waiter
    agent.send((3.0 * jnp.eye(4), jnp.eye(4)), cr, tag=3)
    np.testing.assert_allclose(np.asarray(agent.recv(cr, tag=3)),
                               3.0 * np.eye(4))


def test_mpix_test_polls_to_completion(agent):
    from repro.core import MPIX_Test
    cr = agent.claim("VDP")
    x = jnp.ones(128)
    fut = agent.isend((x, x), cr)
    deadline = time.monotonic() + 30
    done, result = MPIX_Test(fut)
    while not done and time.monotonic() < deadline:
        time.sleep(0.001)
        done, result = MPIX_Test(fut)
    assert done
    np.testing.assert_allclose(np.asarray(result), 128.0, rtol=1e-6)


def test_cancellation_propagates_to_wait(agent):
    """A request cancelled while queued never runs; waiting on it raises."""
    gate = threading.Event()

    def slow(x):
        gate.wait(10)
        return x

    agent.registry.register(KernelRecord(alias="SLOW", fn=slow,
                                         platform="jnp", is_failsafe=True))
    cr = agent.claim("SLOW")
    blocker = agent.isend((jnp.ones(2),), cr)      # occupies the jnp worker
    queued = agent.isend((jnp.ones(2),), cr)
    assert queued.cancel()
    assert queued.cancelled()
    gate.set()
    blocker.result(timeout=30)
    with pytest.raises(HaloCancelledError):
        queued.result(timeout=5)
    # the cancelled future still sits in the mailbox in FIFO position 2
    agent.recv(cr)                                  # blocker's result
    with pytest.raises(HaloCancelledError):
        agent.recv(cr)


def test_execution_error_propagates_to_wait_and_blocking_send(agent):
    def boom(x):
        raise ValueError("kernel exploded")

    agent.registry.register(KernelRecord(alias="BOOM", fn=boom,
                                         platform="jnp", is_failsafe=True))
    cr = agent.claim("BOOM")
    fut = agent.isend((jnp.ones(2),), cr)
    with pytest.raises(ValueError, match="kernel exploded"):
        fut.result(timeout=30)
    assert isinstance(fut.exception(), ValueError)
    # the blocking wrapper surfaces the same error at send time
    cr2 = agent.claim("BOOM")
    with pytest.raises(ValueError, match="kernel exploded"):
        agent.send((jnp.ones(2),), cr2)


def test_async_failsafe_callback(agent):
    """Claim-level fail-safe engages on the async path too."""
    cr = agent.claim("NO_SUCH_KERNEL", failsafe=lambda *a: jnp.zeros((2, 2)))
    fut = agent.isend((jnp.ones((2, 2)),), cr)
    np.testing.assert_allclose(np.asarray(fut.result(timeout=30)), 0.0)


def test_async_overlap_across_substrates(agent):
    """Requests routed to different agents make progress independently: a
    stalled jnp worker must not block an xla-routed request."""
    gate = threading.Event()

    def stall(x):
        gate.wait(10)
        return x

    agent.registry.register(KernelRecord(alias="STALL", fn=stall,
                                         platform="jnp", is_failsafe=True))
    stalled = agent.isend((jnp.ones(2),), agent.claim("STALL"))
    cr = agent.claim("MMM", overrides={"allowed_platforms": ["xla"],
                                       "platform_preference": ["xla"]})
    fast = agent.isend((jnp.eye(8), jnp.eye(8)), cr)
    np.testing.assert_allclose(np.asarray(fast.result(timeout=30)), np.eye(8))
    assert not stalled.done()
    gate.set()
    stalled.result(timeout=30)


def test_isend_mailbox_false_leaves_no_residue(agent):
    """Wait-only consumers opt out of the mailbox so results don't pile up."""
    cr = agent.claim("MMM")
    fut = agent.isend((jnp.eye(4), jnp.eye(4)), cr, mailbox=False)
    np.testing.assert_allclose(np.asarray(fut.result(timeout=30)), np.eye(4))
    with pytest.raises(RuntimeError, match="empty mailbox"):
        agent.recv(cr)


def test_cancel_refused_on_matched_irecv(agent):
    """Once an isend has matched a posted receive, cancelling the receive
    must not drop the result (MPI: no cancel of a matched receive)."""
    gate = threading.Event()

    def slow(x):
        gate.wait(10)
        return x

    agent.registry.register(KernelRecord(alias="SLOW2", fn=slow,
                                         platform="jnp", is_failsafe=True))
    cr = agent.claim("SLOW2")
    waiter = agent.irecv(cr, tag=1)
    agent.isend((jnp.ones(3),), cr, tag=1)      # matches the posted receive
    assert waiter.cancel() is False              # matched -> uncancellable
    gate.set()
    np.testing.assert_allclose(np.asarray(waiter.result(timeout=30)), 1.0)


def test_future_add_done_callback_and_completed(agent):
    seen = []
    fut = HaloFuture.completed(42)
    fut.add_done_callback(lambda f: seen.append(f.result()))
    assert seen == [42]
    cr = agent.claim("VDP")
    x = jnp.ones(8)
    fut2 = agent.isend((x, x), cr)
    fut2.add_done_callback(lambda f: seen.append("done"))
    fut2.result(timeout=30)
    deadline = time.monotonic() + 5
    while len(seen) < 2 and time.monotonic() < deadline:
        time.sleep(0.001)
    assert seen == [42, "done"]
