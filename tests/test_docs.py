"""Docs integrity: every `DESIGN.md §N` reference in the code resolves to a
real section heading — the local twin of the CI docs check."""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _code_refs():
    refs = set()
    for sub in ("src", "tests", "benchmarks", "examples"):
        for p in (ROOT / sub).rglob("*.py"):
            # compound citations ("DESIGN.md §4/§6") contribute every section
            for m in re.finditer(r"DESIGN\.md ((?:§\d+[/,]?)+)", p.read_text()):
                refs.update(re.findall(r"§\d+", m.group(1)))
    return refs


def test_design_and_readme_exist():
    assert (ROOT / "DESIGN.md").is_file()
    assert (ROOT / "README.md").is_file()


def test_no_dangling_design_section_references():
    refs = _code_refs()
    assert refs, "expected the code to cite DESIGN.md sections"
    sections = set(re.findall(r"^## (§\d+) ", (ROOT / "DESIGN.md").read_text(),
                              flags=re.M))
    missing = refs - sections
    assert not missing, f"code cites missing DESIGN.md sections: {sorted(missing)}"
