"""Chaos suite (DESIGN.md §11): whole-system fault injection through
:mod:`repro.testing.faults`.

The headline claims under test: a device group survives a member agent
dying *mid-solve* with bit-identical results (eager and captured paths —
survivors absorb the dead member's ranks, so the shard layout and therefore
the numerics never change), and a straggling attempt is speculatively
re-executed on the next-ranked substrate with exact result parity.  Every
wait is bounded; no test sleeps longer than a few hundred milliseconds at a
time."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AgentDeadError, AgentState, HealthConfig,
                        HealthMonitor, KernelRegistry, RuntimeAgent,
                        default_manifest, halo_graph)
from repro.kernels import register_all
from repro.testing.faults import FaultError, FaultPlan, chaos, engine_chaos

N = 32
ITERS = 4
GROUP = ("xla", "jnp")          # bit-reproducible member pair on CPU


def _session():
    registry = KernelRegistry()
    register_all(registry)
    return RuntimeAgent(registry=registry, manifest=default_manifest())


def _problem(n=N):
    a = (jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
         + n * jnp.eye(n, dtype=jnp.float32))          # diagonally dominant
    b = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    return a, b, jnp.diagonal(a)


def _wait_until(cond, timeout=5.0, what="condition"):
    deadline = time.monotonic() + timeout
    while not cond():
        assert time.monotonic() < deadline, f"{what} not reached in time"
        time.sleep(0.005)


def _eager_jacobi(comm, a, b, d, iters=ITERS):
    """Blocking-verb Jacobi (examples/collective_jacobi.py, shrunk)."""
    A, B, D = comm.scatter(a), comm.scatter(b), comm.scatter(d)
    X = comm.scatter(jnp.zeros_like(b))
    res = 0.0
    for _ in range(iters):
        xs = comm.allgather(X)
        P = comm.map("MVM", list(zip(A, xs)))
        T = comm.map("EWSUB", list(zip(B, P)))
        U = comm.map("EWMM", list(zip(D, X)))
        V = comm.map("EWADD", list(zip(T, U)))
        Xn = comm.map("EWMD", list(zip(V, D)))
        E = comm.map("EWSUB", list(zip(Xn, X)))
        S = comm.map("VDP", list(zip(E, E)))
        res = float(comm.allreduce(S, op="sum")[0])
        X = Xn
    return np.asarray(comm.gather(X)), res


def _captured_jacobi(comm, a, b, d, iters=ITERS):
    """The same loop with each iteration captured as one execution graph."""
    A, B, D = comm.scatter(a), comm.scatter(b), comm.scatter(d)
    X = comm.scatter(jnp.zeros_like(b))
    res = 0.0
    for _ in range(iters):
        with halo_graph(session=comm.session):
            xs = comm.iallgather(X)
            P = comm.imap("MVM", list(zip(A, xs)))
            T = comm.imap("EWSUB", list(zip(B, P)))
            U = comm.imap("EWMM", list(zip(D, X)))
            V = comm.imap("EWADD", list(zip(T, U)))
            Xn = comm.imap("EWMD", list(zip(V, D)))
            E = comm.imap("EWSUB", list(zip(Xn, X)))
            S = comm.imap("VDP", list(zip(E, E)))
            R = comm.iallreduce(S, op="sum")
        X = [n.result(timeout=60) for n in Xn]
        res = float(R[0].result(timeout=60))
    return np.asarray(comm.gather(X)), res


def _chaos_jacobi(run, nth):
    """Fault-free reference vs a run where the xla member dies mid-solve on
    its ``nth`` device call; returns everything the asserts need."""
    a, b, d = _problem()
    ref_sess = _session()
    try:
        x_ref, res_ref = run(ref_sess.comm_split(list(GROUP)), a, b, d)
    finally:
        ref_sess.finalize()

    sess = _session()
    try:
        sess.enable_health_monitor(
            config=HealthConfig(heartbeat_timeout=0.25, poll_interval=0.02,
                                straggler_multiple=0.0), start=True)
        comm = sess.comm_split(list(GROUP))
        with chaos(sess, FaultPlan(platform="xla", mode="die", nth=nth)) as fa:
            x, res = run(comm, a, b, d)
        return x, res, x_ref, res_ref, comm, fa
    finally:
        sess.finalize()


def test_jacobi_survives_member_death_eager():
    x, res, x_ref, res_ref, comm, fa = _chaos_jacobi(_eager_jacobi, nth=12)
    assert fa.failures >= 1                    # the wedge actually happened
    assert "xla" not in comm.platforms         # ranks re-bound onto survivors
    assert comm.size == len(GROUP)             # logical size unchanged
    assert comm.epoch >= 1
    np.testing.assert_array_equal(x, x_ref)    # bit-identical solve
    np.testing.assert_allclose(res, res_ref, rtol=1e-5)


def test_jacobi_survives_member_death_captured():
    x, res, x_ref, res_ref, comm, fa = _chaos_jacobi(_captured_jacobi, nth=15)
    assert fa.failures >= 1
    assert "xla" not in comm.platforms
    assert comm.size == len(GROUP)
    np.testing.assert_array_equal(x, x_ref)
    np.testing.assert_allclose(res, res_ref, rtol=1e-5)


def test_straggler_speculation_result_parity():
    """A hung (not failed) attempt is speculatively re-executed on the
    next-ranked substrate; the backup's result is bit-identical to a plain
    dispatch on that substrate, and the straggler's late result is
    discarded (first completion wins)."""
    a = jax.random.normal(jax.random.PRNGKey(2), (16, 16))
    ref_sess = _session()
    try:
        cr = ref_sess.claim("MMM", overrides={
            "allowed_platforms": ["jnp"], "platform_preference": ["jnp"]})
        ref_sess.send((a, a), cr)
        ref = np.asarray(ref_sess.recv(cr))
    finally:
        ref_sess.finalize()

    sess = _session()
    try:
        sess.enable_health_monitor(
            config=HealthConfig(heartbeat_timeout=60.0, straggler_multiple=1.0,
                                straggler_min_s=0.05), start=False)
        with chaos(sess, FaultPlan(platform="xla", mode="hang",
                                   delay_s=60.0)) as fa:
            cr = sess.claim("MMM", overrides={
                "allowed_platforms": ["xla", "jnp"],
                "platform_preference": ["xla", "jnp"]})
            with halo_graph(session=sess):
                node = sess.isend((a, a), cr)
            _wait_until(lambda: fa.failures >= 1, what="straggler wedged")
            time.sleep(0.06)                   # past the speculation floor
            sess.health.check()
            out = np.asarray(node.result(timeout=30))
        assert node.attempts[0] == "xla"
        assert any(p.endswith("+spec") for p in node.attempts)
        assert node.platform == "jnp"          # the backup won the race
        np.testing.assert_array_equal(out, ref)
    finally:
        sess.finalize()


def test_chaos_context_restores_session():
    """chaos() leaves no residue: original agents back in place, quarantine
    cleared, and the session fully usable afterwards."""
    sess = _session()
    try:
        original = sess.agents["xla"]
        with chaos(sess, FaultPlan(platform="xla", mode="raise")) as fa:
            assert sess.agents["xla"] is fa
            cr = sess.claim("MMM", overrides={
                "allowed_platforms": ["xla", "jnp"],
                "platform_preference": ["xla", "jnp"]})
            sess.send((jnp.eye(4), jnp.eye(4)), cr)
            np.testing.assert_allclose(np.asarray(sess.recv(cr)), np.eye(4),
                                       rtol=1e-5)
            assert fa.failures == 1
        assert sess.agents["xla"] is original
        xla_recs = [r for r in sess.registry.records("MMM")
                    if r.platform == "xla"]
        assert all(not sess.scheduler.is_failed(r) for r in xla_recs)
        cr2 = sess.claim("MMM", overrides={
            "allowed_platforms": ["xla"], "platform_preference": ["xla"]})
        sess.send((jnp.eye(4), jnp.eye(4)), cr2)   # healthy xla again
        np.testing.assert_allclose(np.asarray(sess.recv(cr2)), np.eye(4),
                                   rtol=1e-5)
    finally:
        sess.finalize()


def test_flaky_member_recovers_without_membership_change():
    """A raise-then-recover member (bounded fault window) is quarantined at
    the record level but never declared DEAD: the comm keeps its binding."""
    sess = _session()
    try:
        comm = sess.comm_split(list(GROUP))
        with chaos(sess, FaultPlan(platform="xla", mode="raise", nth=1,
                                   times=1)) as fa:
            a, b = jnp.arange(4.0), jnp.ones(4)
            outs = comm.allreduce([a, b], op="sum")
            np.testing.assert_array_equal(np.asarray(outs[0]),
                                          np.asarray(a) + np.asarray(b))
            assert fa.failures == 1
        assert comm.platforms == GROUP          # membership untouched
        assert comm.epoch == 0
    finally:
        sess.finalize()


# -- paged serving chaos ------------------------------------------------------
# Jitted serving programs inline their kernels at trace time, so FaultyAgent
# never sees a decode call; engine_chaos patches the engine's host entry
# point instead (testing/faults.py).  The claims under test (DESIGN.md §14):
# a decode fault fails exactly the in-flight lanes, every failed lane's
# blocks return to the arena (pool.check() passes, zero leaks), queued
# requests still serve afterwards, and a wedged stepping thread goes DEAD —
# futures fail with AgentDeadError and the arena drains even while the
# device call is still stuck.

@pytest.fixture(scope="module")
def serve_model(rng):
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("h2o-danube-1.8b").reduced()
    model = build_model(cfg)
    return model, model.init(rng)


def _paged_sched(model, params, **kw):
    from repro.serve import PagedEngine, StepScheduler
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("block_size", 8)
    kw.setdefault("chunk_tokens", 0)       # whole-prompt admission
    engine = PagedEngine(model, params, **kw)
    return engine, StepScheduler(engine)


def _assert_arena_drained(pool):
    """Every refcount back at zero, reservations returned, nothing leaked."""
    pool.check()
    assert pool.live_blocks() == 0
    assert pool.reserved == 0
    assert pool.available() == pool.capacity


CASES = [([3, 1, 4, 1, 5], 6), ([2, 7, 1, 8, 2, 8], 6), ([9, 9, 8, 7], 5)]


def test_paged_decode_fault_releases_blocks_and_keeps_serving(serve_model):
    """Kill decode mid-step: the two in-flight lanes fail with the injected
    FaultError and release their blocks; the still-queued third request is
    served afterwards with output identical to a fault-free run."""
    model, params = serve_model
    ref_engine, ref_sched = _paged_sched(model, params)
    ref = ref_sched.submit(CASES[2][0], max_new=CASES[2][1])
    ref_sched.drain()
    expect = ref.result(timeout=60)

    engine, sched = _paged_sched(model, params)
    futs = [sched.submit(p, max_new=n) for p, n in CASES]
    with engine_chaos(engine, mode="raise", nth=2, times=1) as fault:
        with pytest.raises(FaultError):
            while sched.busy():            # 2nd batched decode call faults
                sched.step()
        assert fault.failures == 1
        for f in futs[:2]:                 # the lanes that were in flight
            with pytest.raises(FaultError):
                f.result(timeout=5)
        sched.drain()                      # queued request still serves
    assert futs[2].result(timeout=60) == expect
    assert sched.completed == 1
    _assert_arena_drained(engine.pool)


def test_paged_decode_straggle_recovers_with_parity(serve_model):
    """Hang (not kill) one decode step: the straggling call finishes on the
    real path after the delay, so every request completes bit-identically to
    a fault-free run and the arena still drains to empty."""
    model, params = serve_model
    _, ref_sched = _paged_sched(model, params)
    refs = [ref_sched.submit(p, max_new=n) for p, n in CASES]
    ref_sched.drain()
    expect = [f.result(timeout=60) for f in refs]

    engine, sched = _paged_sched(model, params)
    with engine_chaos(engine, mode="hang", nth=2, times=1,
                      delay_s=0.2) as fault:
        futs = [sched.submit(p, max_new=n) for p, n in CASES]
        sched.drain()
        assert fault.failures == 1
    assert [f.result(timeout=60) for f in futs] == expect
    assert sched.completed == len(CASES)
    _assert_arena_drained(engine.pool)


def test_paged_wedged_decode_goes_dead_and_frees_blocks(serve_model):
    """A stepping thread wedged inside a device call stalls the heartbeat;
    the monitor declares the scheduler DEAD, every in-flight and queued
    future fails with AgentDeadError, and the failed lanes' blocks are back
    in the arena *while the device call is still stuck* (release is
    host-only refcount bookkeeping — DESIGN.md §14)."""
    model, params = serve_model
    engine, sched = _paged_sched(model, params)
    mon = HealthMonitor(HealthConfig(heartbeat_timeout=0.25,
                                     poll_interval=0.02))
    sched.attach_health(mon)
    with engine_chaos(engine, mode="die", nth=1) as fault:
        sched.start()
        futs = [sched.submit(p, max_new=n) for p, n in CASES]
        _wait_until(lambda: fault.calls >= 1, what="decode wedged")
        beats, busy, last = sched.heartbeat()
        assert busy
        assert mon.check(now=last + 0.05)[sched.name] == AgentState.HEALTHY
        assert mon.check(now=last + 0.3)[sched.name] == AgentState.DEAD
        for f in futs:
            with pytest.raises(AgentDeadError):
                f.result(timeout=5)
        _assert_arena_drained(engine.pool)  # freed while decode still wedged
        fault.release()                     # wedged call now fails; loop
    sched.stop(drain=False)                 # survives (step errors are caught)
    assert sched.pending() == 0 and sched.active() == 0
