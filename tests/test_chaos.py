"""Chaos suite (DESIGN.md §11): whole-system fault injection through
:mod:`repro.testing.faults`.

The headline claims under test: a device group survives a member agent
dying *mid-solve* with bit-identical results (eager and captured paths —
survivors absorb the dead member's ranks, so the shard layout and therefore
the numerics never change), and a straggling attempt is speculatively
re-executed on the next-ranked substrate with exact result parity.  Every
wait is bounded; no test sleeps longer than a few hundred milliseconds at a
time."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (HealthConfig, KernelRegistry, RuntimeAgent,
                        default_manifest, halo_graph)
from repro.kernels import register_all
from repro.testing.faults import FaultPlan, chaos

N = 32
ITERS = 4
GROUP = ("xla", "jnp")          # bit-reproducible member pair on CPU


def _session():
    registry = KernelRegistry()
    register_all(registry)
    return RuntimeAgent(registry=registry, manifest=default_manifest())


def _problem(n=N):
    a = (jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
         + n * jnp.eye(n, dtype=jnp.float32))          # diagonally dominant
    b = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    return a, b, jnp.diagonal(a)


def _wait_until(cond, timeout=5.0, what="condition"):
    deadline = time.monotonic() + timeout
    while not cond():
        assert time.monotonic() < deadline, f"{what} not reached in time"
        time.sleep(0.005)


def _eager_jacobi(comm, a, b, d, iters=ITERS):
    """Blocking-verb Jacobi (examples/collective_jacobi.py, shrunk)."""
    A, B, D = comm.scatter(a), comm.scatter(b), comm.scatter(d)
    X = comm.scatter(jnp.zeros_like(b))
    res = 0.0
    for _ in range(iters):
        xs = comm.allgather(X)
        P = comm.map("MVM", list(zip(A, xs)))
        T = comm.map("EWSUB", list(zip(B, P)))
        U = comm.map("EWMM", list(zip(D, X)))
        V = comm.map("EWADD", list(zip(T, U)))
        Xn = comm.map("EWMD", list(zip(V, D)))
        E = comm.map("EWSUB", list(zip(Xn, X)))
        S = comm.map("VDP", list(zip(E, E)))
        res = float(comm.allreduce(S, op="sum")[0])
        X = Xn
    return np.asarray(comm.gather(X)), res


def _captured_jacobi(comm, a, b, d, iters=ITERS):
    """The same loop with each iteration captured as one execution graph."""
    A, B, D = comm.scatter(a), comm.scatter(b), comm.scatter(d)
    X = comm.scatter(jnp.zeros_like(b))
    res = 0.0
    for _ in range(iters):
        with halo_graph(session=comm.session):
            xs = comm.iallgather(X)
            P = comm.imap("MVM", list(zip(A, xs)))
            T = comm.imap("EWSUB", list(zip(B, P)))
            U = comm.imap("EWMM", list(zip(D, X)))
            V = comm.imap("EWADD", list(zip(T, U)))
            Xn = comm.imap("EWMD", list(zip(V, D)))
            E = comm.imap("EWSUB", list(zip(Xn, X)))
            S = comm.imap("VDP", list(zip(E, E)))
            R = comm.iallreduce(S, op="sum")
        X = [n.result(timeout=60) for n in Xn]
        res = float(R[0].result(timeout=60))
    return np.asarray(comm.gather(X)), res


def _chaos_jacobi(run, nth):
    """Fault-free reference vs a run where the xla member dies mid-solve on
    its ``nth`` device call; returns everything the asserts need."""
    a, b, d = _problem()
    ref_sess = _session()
    try:
        x_ref, res_ref = run(ref_sess.comm_split(list(GROUP)), a, b, d)
    finally:
        ref_sess.finalize()

    sess = _session()
    try:
        sess.enable_health_monitor(
            config=HealthConfig(heartbeat_timeout=0.25, poll_interval=0.02,
                                straggler_multiple=0.0), start=True)
        comm = sess.comm_split(list(GROUP))
        with chaos(sess, FaultPlan(platform="xla", mode="die", nth=nth)) as fa:
            x, res = run(comm, a, b, d)
        return x, res, x_ref, res_ref, comm, fa
    finally:
        sess.finalize()


def test_jacobi_survives_member_death_eager():
    x, res, x_ref, res_ref, comm, fa = _chaos_jacobi(_eager_jacobi, nth=12)
    assert fa.failures >= 1                    # the wedge actually happened
    assert "xla" not in comm.platforms         # ranks re-bound onto survivors
    assert comm.size == len(GROUP)             # logical size unchanged
    assert comm.epoch >= 1
    np.testing.assert_array_equal(x, x_ref)    # bit-identical solve
    np.testing.assert_allclose(res, res_ref, rtol=1e-5)


def test_jacobi_survives_member_death_captured():
    x, res, x_ref, res_ref, comm, fa = _chaos_jacobi(_captured_jacobi, nth=15)
    assert fa.failures >= 1
    assert "xla" not in comm.platforms
    assert comm.size == len(GROUP)
    np.testing.assert_array_equal(x, x_ref)
    np.testing.assert_allclose(res, res_ref, rtol=1e-5)


def test_straggler_speculation_result_parity():
    """A hung (not failed) attempt is speculatively re-executed on the
    next-ranked substrate; the backup's result is bit-identical to a plain
    dispatch on that substrate, and the straggler's late result is
    discarded (first completion wins)."""
    a = jax.random.normal(jax.random.PRNGKey(2), (16, 16))
    ref_sess = _session()
    try:
        cr = ref_sess.claim("MMM", overrides={
            "allowed_platforms": ["jnp"], "platform_preference": ["jnp"]})
        ref_sess.send((a, a), cr)
        ref = np.asarray(ref_sess.recv(cr))
    finally:
        ref_sess.finalize()

    sess = _session()
    try:
        sess.enable_health_monitor(
            config=HealthConfig(heartbeat_timeout=60.0, straggler_multiple=1.0,
                                straggler_min_s=0.05), start=False)
        with chaos(sess, FaultPlan(platform="xla", mode="hang",
                                   delay_s=60.0)) as fa:
            cr = sess.claim("MMM", overrides={
                "allowed_platforms": ["xla", "jnp"],
                "platform_preference": ["xla", "jnp"]})
            with halo_graph(session=sess):
                node = sess.isend((a, a), cr)
            _wait_until(lambda: fa.failures >= 1, what="straggler wedged")
            time.sleep(0.06)                   # past the speculation floor
            sess.health.check()
            out = np.asarray(node.result(timeout=30))
        assert node.attempts[0] == "xla"
        assert any(p.endswith("+spec") for p in node.attempts)
        assert node.platform == "jnp"          # the backup won the race
        np.testing.assert_array_equal(out, ref)
    finally:
        sess.finalize()


def test_chaos_context_restores_session():
    """chaos() leaves no residue: original agents back in place, quarantine
    cleared, and the session fully usable afterwards."""
    sess = _session()
    try:
        original = sess.agents["xla"]
        with chaos(sess, FaultPlan(platform="xla", mode="raise")) as fa:
            assert sess.agents["xla"] is fa
            cr = sess.claim("MMM", overrides={
                "allowed_platforms": ["xla", "jnp"],
                "platform_preference": ["xla", "jnp"]})
            sess.send((jnp.eye(4), jnp.eye(4)), cr)
            np.testing.assert_allclose(np.asarray(sess.recv(cr)), np.eye(4),
                                       rtol=1e-5)
            assert fa.failures == 1
        assert sess.agents["xla"] is original
        xla_recs = [r for r in sess.registry.records("MMM")
                    if r.platform == "xla"]
        assert all(not sess.scheduler.is_failed(r) for r in xla_recs)
        cr2 = sess.claim("MMM", overrides={
            "allowed_platforms": ["xla"], "platform_preference": ["xla"]})
        sess.send((jnp.eye(4), jnp.eye(4)), cr2)   # healthy xla again
        np.testing.assert_allclose(np.asarray(sess.recv(cr2)), np.eye(4),
                                   rtol=1e-5)
    finally:
        sess.finalize()


def test_flaky_member_recovers_without_membership_change():
    """A raise-then-recover member (bounded fault window) is quarantined at
    the record level but never declared DEAD: the comm keeps its binding."""
    sess = _session()
    try:
        comm = sess.comm_split(list(GROUP))
        with chaos(sess, FaultPlan(platform="xla", mode="raise", nth=1,
                                   times=1)) as fa:
            a, b = jnp.arange(4.0), jnp.ones(4)
            outs = comm.allreduce([a, b], op="sum")
            np.testing.assert_array_equal(np.asarray(outs[0]),
                                          np.asarray(a) + np.asarray(b))
            assert fa.failures == 1
        assert comm.platforms == GROUP          # membership untouched
        assert comm.epoch == 0
    finally:
        sess.finalize()
