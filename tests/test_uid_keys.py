"""Regression tests for id()-reuse-prone cache keys (the PR-7 _seal hang
class of bug): every cache that can outlive the object it keys must use a
process-unique uid, not id()."""
import gc

import jax.numpy as jnp
import numpy as np

from repro.core.agents import XlaAgent
from repro.core.fusion import _callable_uid, _callable_uids
from repro.core.registry import (KernelAttributes, KernelRecord,
                                 KernelRegistry, clone_record)
from repro.core.scheduler import CostModelScheduler, _record_key


def _rec(alias="MMM", platform="xla", priority=10, fn=None):
    return KernelRecord(alias=alias, fn=fn or (lambda a, b: a @ b),
                        platform=platform, priority=priority,
                        attrs=KernelAttributes(sw_fid=f"fid:{alias}"))


# -- KernelRecord.uid ---------------------------------------------------------
def test_record_uids_are_unique_across_collection():
    """Unlike id(), uids are never reused after a record is collected."""
    seen = set()
    for _ in range(50):
        r = _rec()
        assert r.uid not in seen
        seen.add(r.uid)
        del r
        gc.collect()


def test_clone_record_gets_fresh_uid_and_changes():
    src = _rec()
    clone = clone_record(src, platform="xla@w0", is_failsafe=False)
    assert clone.uid != src.uid
    assert clone.platform == "xla@w0"
    assert clone.alias == src.alias and clone.fn is src.fn
    assert clone.priority == src.priority
    # explicit uid override is honored (resume/debug paths)
    pinned = clone_record(src, uid=999999)
    assert pinned.uid == 999999


def test_clone_registers_and_deregisters_cleanly():
    reg = KernelRegistry()
    src = reg.register(_rec())
    clone = reg.register(clone_record(src, platform="xla@w0"))
    platforms = {r.platform for r in reg.records("MMM")}
    assert platforms == {"xla", "xla@w0"}
    reg.deregister("MMM", "xla@w0")
    assert {r.platform for r in reg.records("MMM")} == {"xla"}
    assert clone.uid != src.uid


# -- XlaAgent jit cache -------------------------------------------------------
def test_xla_jit_cache_keyed_by_uid():
    """Two records wrapping the same fn must not share (or collide on) a
    cache slot via id() reuse: the key is the stable uid."""
    agent = XlaAgent()
    try:
        a = jnp.ones((4, 4))
        r1 = _rec(fn=lambda x, y: x + y)
        out1 = agent._device_execute(r1, (a, a), {})
        assert r1.uid in agent._jit_cache
        r2 = clone_record(r1, platform="xla@w0")
        agent._device_execute(r2, (a, a), {})
        assert r2.uid in agent._jit_cache and r2.uid != r1.uid
        assert len(agent._jit_cache) == 2
        np.testing.assert_array_equal(np.asarray(out1), 2.0)
    finally:
        agent.shutdown(wait=False)


# -- fusion callable uids -----------------------------------------------------
def test_callable_uid_stable_and_distinct():
    def f():
        return 1

    def g():
        return 2

    assert _callable_uid(f) == _callable_uid(f)
    assert _callable_uid(f) != _callable_uid(g)


def test_callable_uid_entry_dies_with_callable():
    """The WeakKeyDictionary must not pin callables alive (and a collected
    callable's id can be reused — the uid never is)."""
    def f():
        return 1

    uid = _callable_uid(f)
    before = len(_callable_uids)
    del f
    gc.collect()
    assert len(_callable_uids) < before or before == 0
    # a fresh callable never resurrects the old uid

    def h():
        return 3

    assert _callable_uid(h) != uid


def test_callable_uid_builtin_fallback():
    # builtins are not weakref-able; they are also immortal, so the id()
    # fallback cannot collide
    assert _callable_uid(len) == _callable_uid(len)


# -- scheduler quarantine keys ------------------------------------------------
def test_mark_failed_key_matches_mark_failed():
    sched = CostModelScheduler()
    r = _rec(alias="EWADD")
    sched.mark_failed(r)
    assert _record_key(r) in sched.failed_record_keys()
    assert sched.is_failed(r)


def test_mark_failed_key_cross_process_form():
    """Raw-key quarantine (the form a worker ships across the wire) is
    equivalent to record-based quarantine and bumps the epoch."""
    sched = CostModelScheduler()
    r = _rec(alias="EWADD", platform="xla@w0")
    e0 = sched.epoch
    sched.mark_failed_key(_record_key(r))
    assert sched.epoch == e0 + 1
    assert sched.is_failed(r)
    sched.clear_failures()
    assert not sched.failed_record_keys()
