"""HaloConfig: the consolidated typed ``HALO_*`` knob surface — precedence
(override > env > default), typo safety, and subsystem pickup."""
import os

import pytest

from repro.core.config import HaloConfig, configure, halo_config, reset_config


def test_defaults_match_dataclass():
    cfg = halo_config()
    assert cfg == HaloConfig()
    assert cfg.fusion is True and cfg.graph_cache == 16
    assert cfg.heartbeat_timeout == 30.0


def test_env_beats_default(monkeypatch):
    monkeypatch.setenv("HALO_FUSION", "0")
    monkeypatch.setenv("HALO_GRAPH_CACHE", "3")
    monkeypatch.setenv("HALO_HEARTBEAT_TIMEOUT", "7.5")
    cfg = halo_config()
    assert cfg.fusion is False
    assert cfg.graph_cache == 3
    assert cfg.heartbeat_timeout == 7.5


def test_override_beats_env_and_never_touches_environ(monkeypatch):
    monkeypatch.setenv("HALO_GRAPH_CACHE", "3")
    try:
        cfg = configure(graph_cache=9, fusion=False)
        assert cfg.graph_cache == 9 and cfg.fusion is False
        assert os.environ["HALO_GRAPH_CACHE"] == "3"
        assert "HALO_FUSION" not in os.environ
        # clearing an override falls back to the env layer
        assert configure(graph_cache=None).graph_cache == 3
    finally:
        reset_config()


def test_unknown_field_raises():
    with pytest.raises(TypeError, match="unknown HaloConfig field"):
        configure(fusoin=True)


def test_snapshot_is_frozen_and_rebuilt_per_call(monkeypatch):
    cfg = halo_config()
    with pytest.raises(dataclasses_frozen_error()):
        cfg.fusion = False
    monkeypatch.setenv("HALO_FUSION", "0")
    assert halo_config().fusion is False     # later reads see the change
    assert cfg.fusion is True                # earlier snapshots don't move


def dataclasses_frozen_error():
    import dataclasses
    return dataclasses.FrozenInstanceError


def test_compile_graph_reads_config_override():
    """HALO_FUSION=off via configure(): compile_graph keeps replay caching
    but skips the fusion pass (fused == nodes count unchanged)."""
    import jax.numpy as jnp

    from repro.core.c2mpi import MPIX_Initialize, halo_session
    from repro.core.graph import halo_graph

    MPIX_Initialize()
    sess = halo_session()
    try:
        configure(fusion=False)
        with halo_graph(sess, launch=False) as g:
            a = sess.dispatch("EWADD", jnp.ones(8), jnp.ones(8))
            b = sess.dispatch("EWMM", a, jnp.ones(8))
            sess.dispatch("EWSUB", b, jnp.ones(8))
        cg = g.compile()
        assert cg.stats["fused_nodes"] == 0
        assert cg.stats["nodes"] == cg.stats["captured_nodes"] == 3
    finally:
        reset_config()


def test_facade_exposes_config():
    from repro import halo
    assert halo.config is halo_config and halo.configure is configure
