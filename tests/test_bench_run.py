"""benchmarks.run summary folding: a crashed section must stub its
artifact entry (empty ratios) so the regression gate reports its baseline
keys as *missing* instead of silently gating a stale artifact."""
import json

from benchmarks.check_regression import main as gate_main
from benchmarks.run import summarize


def _write(root, name, payload):
    (root / name).write_text(json.dumps(payload))


def test_summarize_folds_ratios(tmp_path):
    _write(tmp_path, "BENCH_thing.json", {"speedup_x": 2.0, "t_s": 1.0})
    s = summarize(root=tmp_path)
    ent = s["BENCH_thing"]
    assert ent["ratios"] == {"speedup_x": 2.0}
    assert ent["best_ratio"] == 2.0
    assert json.loads((tmp_path / "BENCH_summary.json").read_text()) == s


def test_crashed_section_stub_overwrites_stale_artifact(tmp_path):
    # last week's artifact would fold fine and let the gate pass on stale
    # numbers; the crash stub must overwrite the folded entry
    _write(tmp_path, "BENCH_smoke_fusion.json",
           {"decode": {"fused_replay_vs_serial_x": 2.0}})
    s = summarize(root=tmp_path, crashed=["fusion"], smoke=True)
    ent = s["BENCH_smoke_fusion"]
    assert ent == {"file": "BENCH_smoke_fusion.json", "error": "crashed",
                   "ratios": {}}


def test_crashed_section_without_artifact_still_stubbed(tmp_path):
    s = summarize(root=tmp_path, crashed=["graph"])
    assert s["BENCH_graph"]["error"] == "crashed"
    assert s["BENCH_graph"]["ratios"] == {}


def test_gate_reports_crashed_section_as_missing(tmp_path, capsys):
    _write(tmp_path, "BENCH_smoke_fusion.json",
           {"decode": {"fused_replay_vs_serial_x": 2.0}})
    _write(tmp_path, "BENCH_smoke_tuning.json", {"best_gain_x": 4.0})
    _write(tmp_path, "BENCH_baseline.json", {
        "tolerance": 0.25, "min_ratio": 1.05, "ratios": {
            "BENCH_smoke_fusion.decode.fused_replay_vs_serial_x": 1.8,
            "BENCH_smoke_tuning.best_gain_x": 3.5,
        }})
    summarize(root=tmp_path, crashed=["fusion"], smoke=True)
    rc = gate_main(["--baseline", str(tmp_path / "BENCH_baseline.json"),
                    "--summary", str(tmp_path / "BENCH_summary.json")])
    out = capsys.readouterr().out
    # tuning still gates (non-vacuous pass); the fusion key is *warned* as
    # missing, not silently passed off the stale artifact on disk
    assert rc == 0
    assert "missing" in out
    assert "BENCH_smoke_fusion.decode.fused_replay_vs_serial_x" in out
