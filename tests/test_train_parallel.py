"""Data-parallel training over C²MPI device groups (DESIGN.md §15).

The §15 contract: at equal global batch the loss history is **bit-identical**
for every member count (1 vs 2 vs 4, local or remote, any substrate mix),
because members only ever sum along one balanced EWADD tree and the
LM_GRAD/ADAMW_STEP records share one jitted callable on every platform.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.agents import RuntimeAgent
from repro.core.c2mpi import MPIX_Initialize, halo_session
from repro.core.manifest import default_manifest
from repro.core.registry import KernelRegistry
from repro.data import SyntheticLM
from repro.kernels import register_all
from repro.models import build_model
from repro.train.fault_tolerance import StragglerPolicy
from repro.train.step_kernels import flatten_params
from repro.train.trainer import TrainHyper, Trainer

ARCH = "h2o-danube-1.8b"


@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    pipe = SyntheticLM(cfg, seq_len=32, global_batch=8)
    data = lambda s: {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
    return cfg, model, data


@pytest.fixture(scope="module")
def session():
    MPIX_Initialize()
    return halo_session()


def _hp():
    return TrainHyper(microbatches=4, warmup_steps=2, total_steps=20)


def _train(session, model, data, platforms, steps=3):
    comm = session.comm_split(platforms)
    tr = Trainer(model=model, hp=_hp(), comm=comm, arch=ARCH,
                 arch_reduced=True, log_every=1)
    state = tr.init_state(jax.random.PRNGKey(0))
    out = tr.run(state, data, steps)
    comm.free()
    return out


def test_member_count_parity(session, setup):
    """1 vs 2 vs 4 members, mixed substrates: bit-identical histories AND
    bit-identical final parameters."""
    cfg, model, data = setup
    s1, h1 = _train(session, model, data, ["xla"])
    s2, h2 = _train(session, model, data, ["xla", "xla"])
    s4, h4 = _train(session, model, data, ["xla", "pallas", "xla", "jnp"])
    assert h1 == h2 == h4
    p1, p2, p4 = (flatten_params(s.params) for s in (s1, s2, s4))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p4))
    # the optimizer moments went through the same tree too
    np.testing.assert_array_equal(np.asarray(flatten_params(s1.opt.nu)),
                                  np.asarray(flatten_params(s4.opt.nu)))


def test_compiled_graph_cache_across_runs(session, setup):
    """A second run with the same topology replays through the §12 compiled
    graph cache (input re-bind, no re-capture) and stays deterministic."""
    cfg, model, data = setup
    _, h_a = _train(session, model, data, ["xla", "xla"], steps=2)
    _, h_b = _train(session, model, data, ["xla", "xla"], steps=2)
    assert h_a == h_b


def test_comm_mode_requires_arch_and_divisibility(session, setup):
    cfg, model, data = setup
    comm = session.comm_split(["xla", "xla"])
    tr = Trainer(model=model, hp=_hp(), comm=comm)
    state = tr.init_state(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="arch"):
        tr.run(state, data, steps=1)
    tr3 = Trainer(model=model, hp=TrainHyper(microbatches=3), comm=comm,
                  arch=ARCH, arch_reduced=True)
    with pytest.raises(ValueError, match="divide"):
        tr3.run(state, data, steps=1)
    comm.free()


def test_chaos_member_death_mid_run_repairs_and_stays_bit_identical(setup):
    """A member dies between steps (§11): the comm re-binds its rank onto
    survivors, the trainer recaptures on the bumped epoch, and the full
    history still matches the fault-free single-member run bit-for-bit."""
    cfg, model, data = setup
    registry = KernelRegistry()
    register_all(registry)
    sess = RuntimeAgent(registry=registry, manifest=default_manifest())
    try:
        ref_comm = sess.comm_split(["xla"])
        tr = Trainer(model=model, hp=_hp(), comm=ref_comm, arch=ARCH,
                     arch_reduced=True, log_every=1)
        state0 = tr.init_state(jax.random.PRNGKey(0))
        _, h_ref = tr.run(state0, data, steps=4)
        ref_comm.free()

        comm = sess.comm_split(["xla", "pallas"])
        killed = []

        def chaotic_data(step):
            if step == 2 and not killed:
                sess.handle_dead_agent(sess.agents["pallas"],
                                       reason="chaos drill")
                killed.append(step)
            return data(step)

        tr = Trainer(model=model, hp=_hp(), comm=comm, arch=ARCH,
                     arch_reduced=True, log_every=1)
        epoch0 = comm.epoch
        _, h_mix = tr.run(tr.init_state(jax.random.PRNGKey(0)),
                          chaotic_data, steps=4)
        assert killed and comm.epoch > epoch0
        assert "pallas" not in comm.platforms
        assert h_mix == h_ref
    finally:
        sess.finalize()


def test_launcher_wires_straggler_and_comm(monkeypatch, tmp_path):
    """repro.launch.train passes its StragglerPolicy into the Trainer (it
    used to construct one and drop it) and builds the --comm group."""
    from repro.launch import train as lt
    seen = {}
    real = lt.Trainer

    def spy(**kw):
        seen.update(kw)
        return real(**kw)

    monkeypatch.setattr(lt, "Trainer", spy)
    lt.main(["--arch", ARCH, "--reduced", "--steps", "2", "--seq-len", "32",
             "--comm", "2"])
    assert isinstance(seen["straggler"], StragglerPolicy)
    assert seen["comm"] is not None and seen["comm"].size == 2
    assert seen["arch"] == ARCH and seen["arch_reduced"] is True
    assert seen["hp"].microbatches == 2


def test_straggler_observed_in_classic_loop(setup):
    cfg, model, data = setup

    class Spy(StragglerPolicy):
        seen = 0

        def observe(self, dt):
            Spy.seen += 1
            return super().observe(dt)

    tr = Trainer(model=model, hp=TrainHyper(), straggler=Spy(), log_every=1)
    tr.run(tr.init_state(jax.random.PRNGKey(0)), data, steps=2)
    assert Spy.seen == 2


@pytest.mark.slow
def test_remote_member_parity(setup):
    """One member rank lives in a spawned worker process: the wire protocol
    carries the LM_GRAD vectors bit-exactly, so the mixed local/remote
    group still reproduces the single-agent history."""
    from repro.distributed.remote import spawn_worker
    cfg, model, data = setup
    registry = KernelRegistry()
    register_all(registry)
    sess = RuntimeAgent(registry=registry, manifest=default_manifest())
    w = spawn_worker("tw-train", devices=2)
    try:
        ref_comm = sess.comm_split(["xla"])
        tr = Trainer(model=model, hp=_hp(), comm=ref_comm, arch=ARCH,
                     arch_reduced=True, log_every=1)
        state0 = tr.init_state(jax.random.PRNGKey(0))
        _, h_ref = tr.run(state0, data, steps=2)
        ref_comm.free()

        agent = w.agent("xla").attach(sess)
        comm = sess.comm_split(["xla", agent.platform])
        tr = Trainer(model=model, hp=_hp(), comm=comm, arch=ARCH,
                     arch_reduced=True, log_every=1)
        _, h_mix = tr.run(tr.init_state(jax.random.PRNGKey(0)), data,
                          steps=2)
        assert h_mix == h_ref
    finally:
        w.kill()
        sess.finalize()
