"""Graph-level kernel fusion + compiled replay (DESIGN.md §12): chain
detection, differential conformance (fused vs. unfused serial dispatch is
*bit-identical* in the default composition mode, across dtypes and pinned
substrates), decompose-on-failure under fault injection, straggler-triggered
decomposition, replay caching with quarantine-epoch invalidation, and the
steady-state no-re-placement guarantee."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CostModelScheduler, GraphError, HealthConfig,
                        KernelRecord, KernelRegistry, RuntimeAgent,
                        abstract_signature, default_manifest, halo_graph)
from repro.kernels import register_all
from repro.testing.faults import FaultPlan, chaos


@pytest.fixture()
def sess():
    registry = KernelRegistry()
    register_all(registry)
    s = RuntimeAgent(registry=registry, manifest=default_manifest())
    yield s
    s.finalize()


def _wait_until(pred, timeout=10.0, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.005)
    raise AssertionError(f"timed out waiting for {what}")


# chain specs: (alias, argspec) where an int indexes the shared inputs list
# and "prev" splices the previous member's output
MIXED4 = [("EWMM", (0, 1)), ("EWADD", ("prev", 2)),
          ("EWSUB", ("prev", 1)), ("RMSNORM", ("prev", 3))]
EW3 = [("EWMM", (0, 1)), ("EWADD", ("prev", 2)), ("EWSUB", ("prev", 1))]


def _inputs(rng, dtype=jnp.float32, m=16, n=128):
    k0, k1, k2 = jax.random.split(rng, 3)
    a = jax.random.normal(k0, (m, n), jnp.float32).astype(dtype)
    b = (jax.random.normal(k1, (m, n), jnp.float32) + 3.0).astype(dtype)
    c = jax.random.normal(k2, (m, n), jnp.float32).astype(dtype)
    gamma = jnp.ones((n,), dtype)
    return [a, b, c, gamma]


def _ov(pin):
    if pin is None:
        return None
    return {"allowed_platforms": [pin], "platform_preference": [pin]}


def _serial(sess, chain, inputs, pin=None):
    """Unfused reference: one blocking dispatch per member."""
    acc = None
    for alias, spec in chain:
        cr = sess.claim(alias, overrides=_ov(pin))
        payload = tuple(acc if s == "prev" else inputs[s] for s in spec)
        acc = sess.isend(payload, cr, mailbox=False).result(60)
    return jax.block_until_ready(acc)


def _capture(sess, chain, inputs, pin=None):
    crs = [sess.claim(alias, overrides=_ov(pin)) for alias, _ in chain]
    with halo_graph(session=sess, launch=False) as g:
        acc = None
        for (alias, spec), cr in zip(chain, crs):
            payload = tuple(acc if s == "prev" else inputs[s] for s in spec)
            acc = sess.isend(payload, cr)
    return g


def _fused(sess, chain, inputs, pin=None, fuse=None):
    cg = _capture(sess, chain, inputs, pin).compile(fuse=fuse)
    gr = cg.replay_async()
    out = gr.wait(timeout=60)
    return cg, gr, jax.block_until_ready(out[-1])


def _bitwise(x, y):
    assert x.dtype == y.dtype and x.shape == y.shape
    assert bool(jnp.array_equal(x, y)), \
        f"max |diff| = {jnp.max(jnp.abs(x - y))}"


# ---------------------------------------------------------------------------
# Chain detection + synthetic records
# ---------------------------------------------------------------------------
def test_chain_detection_and_stats(sess, rng):
    """A 3-deep chain plus an independent node compile to 2 templates; the
    fused record is registered without a jnp fail-safe (decompose *is* the
    fail-safe) and opts out of the agents' outer jit."""
    a, b, c, _ = _inputs(rng)
    w = jnp.eye(16, dtype=jnp.float32)
    chain = [("EWMM", (0, 1)), ("EWADD", ("prev", 2)), ("EWSUB", ("prev", 1))]
    crs = [sess.claim(al, overrides=None) for al, _ in chain]
    cr_mmm = sess.claim("MMM")
    with halo_graph(session=sess, launch=False) as g:
        acc = None
        for (al, spec), cr in zip(chain, crs):
            acc = sess.isend(tuple(acc if s == "prev" else [a, b, c][s]
                                   for s in spec), cr)
        sess.isend((w, w), cr_mmm)               # independent of the chain
    cg = g.compile()
    st = cg.stats
    assert st["captured_nodes"] == 4 and st["nodes"] == 2
    assert st["fused_nodes"] == 1
    assert st["intermediates_eliminated"] == 2
    assert st["pinned_placements"] + st["unplanned_placements"] == 2
    (alias,) = st["fused_aliases"]
    assert alias.startswith("FUSED:EWMM+EWADD+EWSUB@")
    recs = sess.registry.records(alias)
    assert recs and sess.registry.failsafe(alias) is None
    for rec in recs:
        assert rec.tuning_space is not None      # agents must not re-jit
    xla_rec = next(r for r in recs if r.platform == "xla")
    assert xla_rec.cost_model is not None        # sum-of-parts estimate


def test_terminal_rule_ends_chain(sess, rng):
    """MMM may terminate a chain (ewise → matmul epilogue) but nothing
    fuses after it; results stay bit-identical to serial dispatch."""
    a, b, c, _ = _inputs(rng, m=32, n=32)
    chain = [("EWMM", (0, 1)), ("MMM", ("prev", 1)), ("EWADD", ("prev", 2))]
    ref = _serial(sess, chain, [a, b, c])
    cg, gr, out = _fused(sess, chain, [a, b, c])
    assert cg.stats["fused_nodes"] == 1
    assert cg.stats["intermediates_eliminated"] == 1
    assert cg.stats["fused_aliases"][0].startswith("FUSED:EWMM+MMM@")
    assert cg.stats["nodes"] == 2                # EWADD rides outside
    _bitwise(ref, out)


def test_consumers_of_fused_tail_rewire_to_fused_node(sess, rng):
    """Nodes consuming the chain tail (which no longer exists as a node)
    read the fused node's output instead; both diamond outputs match the
    serial reference bitwise."""
    a, b, c, _ = _inputs(rng)
    crs = {al: sess.claim(al) for al in ("EWMM", "EWADD", "EWSUB")}

    def run_serial():
        t = sess.isend((a, b), crs["EWMM"], mailbox=False).result(60)
        u = sess.isend((t, c), crs["EWADD"], mailbox=False).result(60)
        left = sess.isend((u, b), crs["EWMM"], mailbox=False).result(60)
        right = sess.isend((u, c), crs["EWSUB"], mailbox=False).result(60)
        return left, right

    ref_l, ref_r = run_serial()
    with halo_graph(session=sess, launch=False) as g:
        t = sess.isend((a, b), crs["EWMM"])
        u = sess.isend((t, c), crs["EWADD"])
        sess.isend((u, b), crs["EWMM"])
        sess.isend((u, c), crs["EWSUB"])
    cg = g.compile()
    assert cg.stats["fused_nodes"] == 1 and cg.stats["nodes"] == 3
    out_l, out_r = cg.replay(timeout=60)
    _bitwise(ref_l, out_l)
    _bitwise(ref_r, out_r)


# ---------------------------------------------------------------------------
# Differential conformance: fused must be bit-identical to unfused serial
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("pin", [None, "xla"])
def test_fused_chain_bitwise_vs_serial(sess, rng, dtype, pin):
    """Default-mode fusion (composition loop over per-member executables)
    is bit-identical to one-kernel-at-a-time dispatch."""
    inputs = _inputs(rng, dtype)
    ref = _serial(sess, MIXED4, inputs, pin=pin)
    cg, gr, out = _fused(sess, MIXED4, inputs, pin=pin)
    assert cg.stats["fused_nodes"] == 1 and cg.stats["nodes"] == 1
    assert "decomposed" not in gr.nodes[0].attempts
    _bitwise(ref, out)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_pure_ewise_chain_on_pallas_bitwise(sess, rng, dtype):
    """A pure element-wise chain pinned to pallas runs the fused pallas
    composition (loop over member pallas kernels) — still bit-identical."""
    inputs = _inputs(rng, dtype)
    ref = _serial(sess, EW3, inputs, pin="pallas")
    cg, gr, out = _fused(sess, EW3, inputs, pin="pallas")
    node = gr.nodes[0]
    assert "decomposed" not in node.attempts
    assert node.platform == "pallas"
    _bitwise(ref, out)


def test_js_chain_bitwise(sess, rng):
    """Jacobi sweeps chain through x: mixed-arity members fuse via the XLA
    composition and match three serial sweeps bitwise."""
    k0, k1 = jax.random.split(rng)
    n = 64
    a = jax.random.normal(k0, (n, n)) + n * jnp.eye(n)   # diag-dominant
    b = jax.random.normal(k1, (n,))
    x0 = jnp.zeros((n,))

    def run(isend):
        x = x0
        for _ in range(3):
            x = isend(x)
        return x

    cr = sess.claim("JS")
    ref = jax.block_until_ready(run(
        lambda x: sess.isend((a, x, b), cr, mailbox=False).result(60)))
    with halo_graph(session=sess, launch=False) as g:
        run(lambda x: sess.isend((a, x, b), cr))
    cg = g.compile()
    assert cg.stats["fused_nodes"] == 1
    assert cg.stats["fused_aliases"][0].startswith("FUSED:JS+JS+JS@")
    (out,) = cg.replay(timeout=60)
    _bitwise(ref, jax.block_until_ready(out))


@pytest.mark.parametrize("pin", ["pallas", "jnp"])
def test_mixed_chain_pinned_off_xla_decomposes_bitwise(sess, rng, pin):
    """A mixed chain pinned to a substrate with no fused record decomposes
    back into member nodes at replay — and still matches serial bitwise."""
    inputs = _inputs(rng)
    ref = _serial(sess, MIXED4, inputs, pin=pin)
    cg, gr, out = _fused(sess, MIXED4, inputs, pin=pin)
    assert cg.stats["fused_nodes"] == 1
    node = gr.nodes[0]
    assert "decomposed" in node.attempts
    assert node.platform == pin                  # tail member's substrate
    # shadow member nodes are hidden from the output frontier
    assert gr.outputs == [node]
    _bitwise(ref, out)


# ---------------------------------------------------------------------------
# Failure + straggler semantics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_fail_then_decompose_bitwise(sess, rng, dtype):
    """A fused record whose execution raises quarantines and decomposes;
    the member-chain result is bit-identical to never having fused."""
    inputs = _inputs(rng, dtype)
    ref = _serial(sess, MIXED4, inputs)
    cg = _capture(sess, MIXED4, inputs).compile()
    (alias,) = cg.stats["fused_aliases"]
    with chaos(sess, FaultPlan(platform="xla", mode="raise",
                               aliases=[alias])) as fa:
        gr = cg.replay_async()
        out = jax.block_until_ready(gr.wait(timeout=60)[-1])
        assert fa.failures >= 1
    node = gr.nodes[0]
    assert "decomposed" in node.attempts
    assert sess.registry.records(alias)          # record stays registered…
    _bitwise(ref, out)                           # …and the fallback matches


def test_straggler_fused_node_decomposes(sess, rng):
    """A straggling fused attempt with no second fused record speculates by
    decomposing: the member chain races the straggler, first win counts."""
    inputs = _inputs(rng)
    ref = _serial(sess, MIXED4, inputs)
    sess.enable_health_monitor(
        config=HealthConfig(heartbeat_timeout=60.0, straggler_multiple=1.0,
                            straggler_min_s=0.05), start=False)
    cg = _capture(sess, MIXED4, inputs).compile()
    (alias,) = cg.stats["fused_aliases"]
    with chaos(sess, FaultPlan(platform="xla", mode="hang", delay_s=60.0,
                               aliases=[alias])) as fa:
        gr = cg.replay_async()
        _wait_until(lambda: fa.failures >= 1, what="fused attempt wedged")
        time.sleep(0.06)                         # past the speculation floor
        sess.health.check()
        node = gr.nodes[0]
        assert "decomposed+spec" in node.attempts
        fa.release()                             # unwedge the xla worker
        out = jax.block_until_ready(gr.wait(timeout=60)[-1])
    _bitwise(ref, out)


# ---------------------------------------------------------------------------
# CompiledGraph cache + replay
# ---------------------------------------------------------------------------
def test_replay_cache_hit_and_epoch_invalidation(sess, rng):
    """Re-compiling an identical capture returns the cached CompiledGraph;
    a quarantine change (scheduler epoch bump) forces a fresh plan."""
    inputs = _inputs(rng)
    cg1 = _capture(sess, EW3, inputs).compile()
    cg2 = _capture(sess, EW3, inputs).compile()
    assert cg2 is cg1
    assert cg1.stats["cache_hits"] == 1
    rec = sess.registry.records("MMM")[0]
    sess.scheduler.mark_failed(rec)              # epoch moves → stale plans
    cg3 = _capture(sess, EW3, inputs).compile()
    assert cg3 is not cg1
    sess.scheduler.clear_failures()


def test_compiled_graph_cache_is_bounded(monkeypatch, sess, rng):
    monkeypatch.setenv("HALO_GRAPH_CACHE", "2")
    for m in (8, 16, 24):
        _capture(sess, EW3, _inputs(rng, m=m)).compile()
    assert len(sess._compiled_graphs) == 2


def test_replay_updates_and_validation(sess, rng):
    """replay(updates=) swaps input slots by index; shape/dtype mismatches
    and unknown slots are rejected (recompile instead of silent garbage)."""
    inputs = _inputs(rng)
    cg, _, out = _fused(sess, EW3, inputs)
    slot = cg.slot_of(inputs[0])
    assert slot is not None
    a2 = inputs[0] * 2.0
    ref2 = _serial(sess, EW3, [a2] + inputs[1:])
    (out2,) = cg.replay(updates={slot: a2}, timeout=60)
    _bitwise(ref2, jax.block_until_ready(out2))
    with pytest.raises(GraphError):
        cg.replay(updates={slot: jnp.zeros((2, 2))})
    with pytest.raises(GraphError):
        cg.replay(updates={99: a2})


def test_steady_state_replay_is_fully_pinned(sess, rng):
    """After compile, replays place every node through the pinned fast
    path — no re-capture, no re-scoring, no re-wiring in steady state."""
    inputs = _inputs(rng)
    cg = _capture(sess, MIXED4, inputs).compile()
    for _ in range(3):
        cg.replay(timeout=60)
    assert cg.stats["replays"] == 3
    assert cg.stats["placements_scored_last"] == 0
    assert cg.stats["placements_pinned_last"] == cg.stats["nodes"]


def test_halo_fusion_env_disables_fusion(monkeypatch, sess, rng):
    """HALO_FUSION=0 keeps replay caching but skips the fusion pass; the
    unfused compiled graph still matches serial bitwise."""
    monkeypatch.setenv("HALO_FUSION", "0")
    inputs = _inputs(rng)
    ref = _serial(sess, MIXED4, inputs)
    cg, gr, out = _fused(sess, MIXED4, inputs)
    assert cg.stats["fused_nodes"] == 0
    assert cg.stats["nodes"] == cg.stats["captured_nodes"] == 4
    _bitwise(ref, out)


def test_contract_mode_registers_single_jit_records(monkeypatch, sess, rng):
    """HALO_FUSION_CONTRACT=1 trades bit-exactness for a single-jit chain
    program (+ generated Pallas chain kernel for pure-ewise chains); the
    result stays numerically close to serial."""
    monkeypatch.setenv("HALO_FUSION_CONTRACT", "1")
    inputs = _inputs(rng)
    ref = _serial(sess, EW3, inputs)
    cg, gr, out = _fused(sess, EW3, inputs)
    (alias,) = cg.stats["fused_aliases"]
    platforms = {r.platform for r in sess.registry.records(alias)}
    assert platforms == {"xla", "pallas"}        # chain kernel registered
    np.testing.assert_allclose(np.asarray(ref, np.float32),
                               np.asarray(out, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_compile_rejects_launched_and_foreign_graphs(sess, rng):
    a, b, _, _ = _inputs(rng)
    cr = sess.claim("EWMM")
    with halo_graph(session=sess) as g:          # launched on exit
        sess.isend((a, b), cr)
    g.wait(timeout=60)
    with pytest.raises(GraphError, match="already launched"):
        g.compile()
    fut = sess.isend((a, b), cr, mailbox=False)
    fut.result(60)
    with halo_graph(session=sess, launch=False) as g2:
        sess.isend((fut, b), cr)                 # gated on a foreign future
    with pytest.raises(GraphError, match="outside this graph"):
        g2.compile()


# ---------------------------------------------------------------------------
# Cost + scheduler plumbing
# ---------------------------------------------------------------------------
def test_sum_of_parts_cost_model(sess, rng):
    """A fused record estimates as the sum of its members' best estimates
    until measured — and refuses to guess before any member is known."""
    inputs = _inputs(rng)
    cg = _capture(sess, EW3, inputs).compile()
    (alias,) = cg.stats["fused_aliases"]
    rec = next(r for r in sess.registry.records(alias) if r.platform == "xla")
    abstract = tuple(jax.ShapeDtypeStruct(x.shape, x.dtype)
                     for x in inputs[:3])
    with pytest.raises(ValueError):
        rec.cost_model(*abstract)                # no member estimates yet
    sched = sess.scheduler
    sig = abstract_signature(abstract[:2])
    per_member = {"EWMM": 3e-4, "EWADD": 2e-4, "EWSUB": 1e-4}
    for al, seconds in per_member.items():
        mrec = next(r for r in sess.registry.records(al)
                    if r.platform == "xla")
        sched.observe(mrec, sig, seconds)        # warmup sample (discarded)
        sched.observe(mrec, sig, seconds)
    assert rec.cost_model(*abstract) == pytest.approx(sum(
        per_member.values()), rel=1e-6)


def test_scheduler_epoch_tracks_quarantine_changes():
    sched = CostModelScheduler()
    rec = KernelRecord(alias="K", fn=lambda a: a, platform="xla")
    e0 = sched.epoch
    sched.mark_failed(rec)
    assert sched.epoch == e0 + 1
    sched.clear_failures()
    assert sched.epoch == e0 + 2
    sched.clear_failures()                       # nothing quarantined: no-op
    assert sched.epoch == e0 + 2
