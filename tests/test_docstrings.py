"""Public-API docstring integrity for the core modules (CI twin).

Every module in :data:`MODULES` must declare ``__all__``, every entry must
resolve, and every function/class entry must carry a docstring whose first
line is a real one-line summary.  Runnable standalone (the CI step):

    PYTHONPATH=src python tests/test_docstrings.py
"""
import importlib
import inspect
import sys

MODULES = [
    "repro.core.c2mpi",
    "repro.core.collective",
    "repro.core.graph",
    "repro.core.registry",
    "repro.core.scheduler",
    "repro.core.tuning",
    "repro.testing.faults",
]


def docstring_problems(module_name):
    """All __all__-coverage problems for one module, as strings."""
    mod = importlib.import_module(module_name)
    exported = getattr(mod, "__all__", None)
    if not exported:
        return [f"{module_name}: missing or empty __all__"]
    problems = []
    for sym in exported:
        obj = getattr(mod, sym, None)
        if obj is None:
            problems.append(f"{module_name}.{sym}: in __all__ but undefined")
            continue
        if not (inspect.isfunction(obj) or inspect.isclass(obj)):
            continue                    # constants (tuples, registries, …)
        doc = inspect.getdoc(obj)
        if not doc or not doc.strip().splitlines()[0].strip():
            problems.append(
                f"{module_name}.{sym}: missing one-line docstring summary")
    return problems


def test_public_api_docstrings():
    problems = []
    for name in MODULES:
        problems += docstring_problems(name)
    assert not problems, "\n".join(problems)


def main():
    """Script entry: print problems and exit non-zero if any."""
    problems = []
    for name in MODULES:
        probs = docstring_problems(name)
        problems += probs
        status = "FAIL" if probs else "ok"
        print(f"{name}: {status}")
    for p in problems:
        print(f"  {p}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
